//! The end-to-end trainer: graph -> sampler -> feature store -> PJRT step.
//!
//! Every epoch produces two time breakdowns (DESIGN.md §5):
//!
//! * **simulated** — the paper-testbed estimate: sampling and training via
//!   [`ComputeModel`], feature copy via the interconnect models.  This is
//!   what the Fig. 8 bench compares across access modes.  On top of the
//!   additive per-stage breakdown, the discrete-event overlap engine
//!   ([`crate::coordinator::schedule`], DESIGN.md §9) schedules every
//!   step's stages onto the shared resources and reports the *pipelined*
//!   epoch time plus critical-path attribution.
//! * **measured** — real wall-clock on this machine.  The epoch actually
//!   runs through the staged pipeline executor (sample ∥ gather ∥ train
//!   behind `queue_depth`-bounded queues), so the per-queue backpressure
//!   gauges land in the report next to the simulated critical path.  The
//!   stages process steps in FIFO order, which keeps batches, gathers,
//!   and loss trajectories bitwise identical to a serial loop.

use std::path::Path;
use std::sync::Mutex;

use crate::config::{AccessMode, Backend, RunConfig};
use crate::coordinator::costmodel::ComputeModel;
use crate::coordinator::power::{epoch_power, PowerReport};
use crate::coordinator::schedule::{schedule_epoch, OverlapParams, OverlapReport};
use crate::error::{Error, Result};
use crate::featurestore::nvme::NvmeStoreConfig;
use crate::featurestore::sharded::ShardConfig;
use crate::featurestore::tiered::TierConfig;
use crate::featurestore::{FeatureStore, NvmeStats, ShardStats, TierStats};
use crate::interconnect::{LinkBytes, ResourceDemand, ResourceKind};
use crate::pipeline::executor::{run_pipeline, PipelineReport};
use crate::runtime::native::{self, NativeTrainState};
use crate::runtime::state::{StepBatch, TrainState};
use crate::runtime::{ArtifactKind, ArtifactSpec, LoadedArtifact, Manifest, Runtime};
use crate::graph::{Csr, DatasetPreset};
use crate::sampler::{AggregatePlan, NeighborSampler};
use crate::util::rng::Rng;
use crate::util::timer::Timer;

/// Epoch time breakdown (the stacked bars of paper Fig. 8).
#[derive(Clone, Copy, Debug, Default)]
pub struct Breakdown {
    /// Neighbor sampling + subgraph construction.
    pub sample_s: f64,
    /// Feature gather + host->device transfer ("Feature Copy").
    pub transfer_s: f64,
    /// Forward/backward/update ("Training").
    pub train_s: f64,
    /// Everything else (batch assembly, bookkeeping).
    pub other_s: f64,
}

impl Breakdown {
    pub fn total_s(&self) -> f64 {
        self.sample_s + self.transfer_s + self.train_s + self.other_s
    }
}

/// Per-epoch minibatch-deduplication accounting (DESIGN.md §10): how many
/// feature rows the sampled batches *requested* versus how many the
/// [`GatherPlan`](crate::sampler::GatherPlan) actually fetched, and the
/// useful transfer bytes the compaction saved.  With `--no-dedup` the
/// plan is skipped entirely (`enabled = false`, unique == requested,
/// nothing saved).
#[derive(Clone, Copy, Debug, Default)]
pub struct DedupReport {
    /// Whether gather deduplication ran this epoch (`RunConfig::dedup`).
    pub enabled: bool,
    /// Feature rows the sampled batches requested (duplicates included).
    pub requested_rows: u64,
    /// Distinct rows actually fetched after per-batch compaction.
    pub unique_rows: u64,
    /// Useful payload bytes the compaction eliminated
    /// (`(requested - unique) x row_bytes`).  An *upper bound* on the
    /// link-byte savings: duplicate rows a hot tier would have served
    /// never crossed a link in the first place (and `GpuResident` moves
    /// no link bytes at all) — compare `EpochReport::bytes_on_link`
    /// across dedup on/off for the exact link delta.
    pub bytes_saved: u64,
}

impl DedupReport {
    /// Requested over unique rows (≥ 1; 1.0 on an empty epoch).
    pub fn ratio(&self) -> f64 {
        if self.unique_rows == 0 {
            1.0
        } else {
            self.requested_rows as f64 / self.unique_rows as f64
        }
    }
}

/// Per-epoch aggregation push-down accounting (`--aggregate-pushdown`,
/// DESIGN.md §14): what the epoch's gathers would have paid shipping raw
/// neighbor rows versus what the pushed-down streams actually paid, plus
/// the near-memory reduction work that bought the difference.  With
/// `--no-pushdown` (the default) nothing here is populated
/// (`enabled = false`) and every report reproduces the pre-pushdown
/// numbers bit-exactly.
#[derive(Clone, Copy, Debug, Default)]
pub struct PushdownReport {
    /// Whether aggregation push-down priced this epoch's transfers.
    pub enabled: bool,
    /// Link bytes the raw (gather-every-neighbor-row) path pays for the
    /// same batches — the store's unchanged gather costing, accumulated
    /// alongside for the reduction factor.
    pub raw_bytes_on_link: u64,
    /// Link bytes the pushed-down epoch actually paid (self streams +
    /// aggregate streams; this is what lands in
    /// [`EpochReport::bytes_on_link`] when push-down is on).
    pub pushed_bytes_on_link: u64,
    /// Aggregate-stream share of `pushed_bytes_on_link` (partial rows +
    /// counts + the NVMe block reads behind storage-side partials).
    pub agg_bytes_on_link: u64,
    /// Destination self-stream rows priced (post-dedup when dedup is on).
    pub dst_rows: u64,
    /// Masked neighbor slots the aggregate streams replaced.
    pub neighbor_rows: u64,
    /// Partial-aggregate rows shipped across all tiers.
    pub agg_rows: u64,
    /// Near-memory reduction FLOPs (one add per off-GPU neighbor element).
    pub near_mem_flops: u64,
    /// Near-memory reduction seconds (serialized into the simulated
    /// transfer time; drives the power model's near-memory duty cycle).
    pub near_mem_s: f64,
}

impl PushdownReport {
    /// Raw over pushed-down link bytes (≥ 0; 1.0 when nothing moved).
    pub fn reduction(&self) -> f64 {
        if self.pushed_bytes_on_link == 0 {
            1.0
        } else {
            self.raw_bytes_on_link as f64 / self.pushed_bytes_on_link as f64
        }
    }
}

/// One epoch's results.
#[derive(Clone, Debug, Default)]
pub struct EpochReport {
    pub steps: u64,
    pub breakdown_sim: Breakdown,
    pub breakdown_measured: Breakdown,
    pub losses: Vec<f32>,
    pub accs: Vec<f32>,
    pub bytes_on_link: u64,
    pub requests: u64,
    /// CPU seconds the transfer path consumed (simulated testbed).
    pub cpu_gather_s: f64,
    pub power: PowerReport,
    /// Hot-tier statistics for this epoch (`Tiered` mode only): counters
    /// are per-epoch deltas, gauges (hot bytes/capacity) are end-of-epoch.
    pub tier: Option<TierStats>,
    /// Per-GPU shard statistics for this epoch (`Sharded` mode only):
    /// local/peer/host row+byte+time splits and the load-imbalance factor
    /// (counters are per-epoch deltas, gauges end-of-epoch).
    pub shard: Option<ShardStats>,
    /// Three-tier storage statistics for this epoch (`Nvme` mode only):
    /// GPU-hit / host / storage row splits, block-read counts, and I/O
    /// amplification (counters are per-epoch deltas, gauges end-of-epoch).
    pub nvme: Option<NvmeStats>,
    /// Measured pipeline execution of this epoch: wall clock, per-stage
    /// busy time, and the q1/q2 push/pop blocked seconds (the measured
    /// backpressure printed next to the simulated critical path).
    pub pipeline: PipelineReport,
    /// Simulated overlapped timeline from the discrete-event engine:
    /// serial vs pipelined epoch seconds, per-resource busy time, and
    /// critical-path attribution (DESIGN.md §9).
    pub overlap: OverlapReport,
    /// Minibatch gather-deduplication accounting (DESIGN.md §10):
    /// requested vs unique rows and the transfer bytes saved.
    pub dedup: DedupReport,
    /// Aggregation push-down accounting (DESIGN.md §14): raw vs
    /// pushed-down link bytes, the traffic-reduction factor, and the
    /// near-memory reduction work.
    pub pushdown: PushdownReport,
}

impl EpochReport {
    pub fn mean_loss(&self) -> f32 {
        if self.losses.is_empty() {
            return 0.0;
        }
        self.losses.iter().sum::<f32>() / self.losses.len() as f32
    }

    pub fn final_loss(&self) -> f32 {
        self.losses.last().copied().unwrap_or(0.0)
    }
}

/// Build the feature store a run config asks for; `Tiered` mode derives
/// its hot-set placement (degree ranking) and capacity from the graph and
/// the config's `hot_frac`/`gpu_reserve_frac`/`tier_promote` knobs;
/// `Sharded` additionally partitions the table per
/// `num_gpus`/`shard_policy`; `Nvme` bounds the host tier by `host_frac`
/// and spills the degree-ranking tail to the simulated NVMe cold store.
pub(crate) fn build_store(
    cfg: &RunConfig,
    graph: &Csr,
    preset: &DatasetPreset,
) -> Result<FeatureStore> {
    let tier_cfg = (cfg.mode == AccessMode::Tiered).then(|| TierConfig::from_run(cfg, graph));
    let shard_cfg = (cfg.mode == AccessMode::Sharded).then(|| ShardConfig::from_run(cfg, graph));
    let nvme_cfg = (cfg.mode == AccessMode::Nvme).then(|| NvmeStoreConfig::from_run(cfg, graph));
    let mut store = FeatureStore::build_quantized(
        graph.num_nodes(),
        preset.feat_dim as usize,
        preset.classes,
        cfg.mode,
        &cfg.system,
        cfg.seed ^ 0xFEA7,
        cfg.precision,
        tier_cfg,
        shard_cfg,
        nvme_cfg,
    )?;
    store.set_gather_workers(cfg.sampler_workers.max(1));
    Ok(store)
}

/// Apply a run's `--classes` override onto its dataset preset — shared
/// by the trainer and the inference runner so the semantics cannot
/// drift.  `RunConfig::validate` already rejected values outside
/// `[1, 2^20]` (labels are `node_hash % classes`).
pub(crate) fn apply_classes_override(cfg: &RunConfig, preset: &mut DatasetPreset) {
    if let Some(c) = cfg.classes {
        preset.classes = c;
    }
}

/// Reject a PJRT artifact whose compiled class count diverges from an
/// overridden label count: labels would be hashed modulo one value while
/// the compiled graph computes loss over another — the run would finish
/// with silently wrong numbers.
pub(crate) fn check_artifact_classes(
    cfg: &RunConfig,
    spec: &ArtifactSpec,
    classes: u32,
) -> Result<()> {
    if cfg.classes.is_some() && spec.classes != classes as usize {
        return Err(Error::Config(format!(
            "artifact {} compiled for {} classes; --classes overrode the run to {} \
             (drop the override or re-run `make artifacts`)",
            spec.name, spec.classes, classes
        )));
    }
    Ok(())
}

/// End-to-end trainer over one (dataset, arch, mode, system) configuration.
pub struct Trainer {
    pub cfg: RunConfig,
    pub preset: DatasetPreset,
    pub scale: u32,
    graph: Csr,
    store: FeatureStore,
    compute: Option<ComputeModel>,
    artifact: Option<LoadedArtifact>,
    state: Option<TrainState>,
    native: Option<NativeTrainState>,
    rng: Rng,
}

impl Trainer {
    /// Build the full stack.  When `cfg.skip_train` is set the PJRT runtime
    /// is not loaded (pipeline/transfer accounting only — used by benches
    /// that sweep all 12 variants without paying 12 compilations).
    pub fn new(cfg: RunConfig) -> Result<Trainer> {
        // Programmatic configs (benches, library users) bypass the CLI's
        // validation pass; reject impossible shapes here (an empty
        // `fanouts` would otherwise panic deep in the sampler).
        cfg.validate()?;
        let mut preset = DatasetPreset::by_abbv(&cfg.dataset)
            .ok_or_else(|| Error::Config(format!("unknown dataset `{}`", cfg.dataset)))?;
        apply_classes_override(&cfg, &mut preset);
        let scale = preset.scale_for_budget(cfg.scale, cfg.feature_budget);
        if scale != cfg.scale {
            log::info!(
                "dataset {}: scale raised {} -> {} to fit feature budget",
                preset.abbv,
                cfg.scale,
                scale
            );
        }
        let t = Timer::start();
        let graph = preset.build_graph(scale, cfg.seed)?;
        log::info!(
            "graph {}: {} nodes, {} edges (scale 1/{scale}) in {:.2}s",
            preset.abbv,
            graph.num_nodes(),
            graph.num_edges(),
            t.elapsed_s()
        );
        if cfg.batch > graph.num_nodes() {
            // `epoch_seeds` drops the remainder (DGL drop_last), so an
            // oversized batch silently yields *zero* batches and every
            // per-epoch average would divide by an empty step list.
            return Err(Error::Config(format!(
                "batch {} exceeds the graph's {} nodes (dataset {} at scale {scale}): every \
                 epoch would yield zero batches — lower --batch or --scale",
                cfg.batch,
                graph.num_nodes(),
                preset.abbv
            )));
        }
        let store = build_store(&cfg, &graph, &preset)?;

        let (artifact, state, compute, native) = if cfg.skip_train {
            // No PJRT, but still read the manifest (when present) so the
            // simulated train/sample estimates use the artifact's true
            // shapes — benches sweep all variants without 12 compilations.
            let compute = Manifest::load(Path::new(&cfg.artifacts_dir))
                .ok()
                .and_then(|m| m.get(&cfg.artifact_name()).ok().cloned())
                .map(|spec| ComputeModel::from_spec(&spec));
            (None, None, compute, None)
        } else {
            let manifest = Manifest::load(Path::new(&cfg.artifacts_dir));
            let use_pjrt = match cfg.backend {
                Backend::Pjrt => true,
                Backend::Native => false,
                // Auto: the PJRT path when *this run's* artifact exists,
                // the built-in native trainer otherwise.  Config/artifact
                // mismatches (batch, fanouts, dims) still error below —
                // they mean the artifact is present but stale.
                Backend::Auto => manifest
                    .as_ref()
                    .map(|m| m.get(&cfg.artifact_name()).is_ok())
                    .unwrap_or(false),
            };
            if use_pjrt {
                let manifest = manifest?;
                let spec = manifest.get(&cfg.artifact_name())?;
                if spec.kind != ArtifactKind::Train {
                    return Err(Error::Runtime(format!(
                        "{} is not a train artifact",
                        spec.name
                    )));
                }
                if spec.batch != cfg.batch || spec.fanouts != cfg.fanouts {
                    return Err(Error::Config(format!(
                        "artifact {} built for batch {} fanouts {:?}; run config has {} {:?} \
                         (re-run `make artifacts` with matching flags)",
                        spec.name, spec.batch, spec.fanouts, cfg.batch, cfg.fanouts
                    )));
                }
                if spec.in_dim != preset.feat_dim as usize {
                    return Err(Error::Config(format!(
                        "artifact in_dim {} != dataset feat dim {}",
                        spec.in_dim, preset.feat_dim
                    )));
                }
                check_artifact_classes(&cfg, spec, preset.classes)?;
                let runtime = Runtime::cpu()?;
                let loaded = runtime.load(Path::new(&cfg.artifacts_dir), spec)?;
                let state = TrainState::init(spec, cfg.seed ^ 0x9A23)?;
                let compute = ComputeModel::from_spec(spec);
                (Some(loaded), Some(state), Some(compute), None)
            } else {
                log::info!(
                    "backend: native trainer (softmax regression, lr {}) — no AOT artifacts \
                     needed",
                    native::DEFAULT_LR
                );
                let mut nstate = NativeTrainState::init(
                    preset.feat_dim as usize,
                    preset.classes,
                    native::DEFAULT_LR,
                    cfg.seed ^ 0x9A23,
                );
                nstate.set_workers(cfg.sampler_workers.max(1));
                (None, None, None, Some(nstate))
            }
        };

        let rng = Rng::new(cfg.seed);
        Ok(Trainer {
            cfg,
            preset,
            scale,
            graph,
            store,
            compute,
            artifact,
            state,
            native,
            rng,
        })
    }

    pub fn graph(&self) -> &Csr {
        &self.graph
    }

    pub fn store(&self) -> &FeatureStore {
        &self.store
    }

    /// Compute model (None when skip_train and no artifact was loaded).
    pub fn compute_model(&self) -> Option<&ComputeModel> {
        self.compute.as_ref()
    }

    /// Steps one epoch would run at full size.
    pub fn steps_per_epoch(&self) -> u64 {
        let by_graph = (self.graph.num_nodes() / self.cfg.batch) as u64;
        if self.cfg.steps_per_epoch > 0 {
            by_graph.min(self.cfg.steps_per_epoch as u64)
        } else {
            by_graph
        }
    }

    /// Run one training epoch.
    ///
    /// The measured side runs through the staged pipeline executor
    /// (sample ∥ gather ∥ train behind bounded queues); each stage
    /// processes steps in FIFO order, so batches and loss trajectories
    /// are bitwise identical to a serial loop — only the wall clock and
    /// the queue-wait gauges change.
    pub fn run_epoch(&mut self) -> Result<EpochReport> {
        let max_steps = self.steps_per_epoch() as usize;
        let queue_depth = self.cfg.queue_depth;
        let sampler = NeighborSampler::new(&self.graph, &self.cfg.fanouts, self.preset.classes);
        let mut rng = self.rng.fork(self.state.as_ref().map(|s| s.steps).unwrap_or(0));
        let seeds_all = sampler.epoch_seeds(self.cfg.batch, &mut rng);
        let seeds: Vec<Vec<u32>> = seeds_all.into_iter().take(max_steps).collect();

        let mut report = EpochReport::default();
        let dim = self.store.dim();
        let dedup_on = self.cfg.dedup;
        let pushdown_on = self.cfg.aggregate_pushdown;
        let row_bytes = self.cfg.precision.row_bytes(dim);
        report.dedup.enabled = dedup_on;
        report.pushdown.enabled = pushdown_on;
        let tier_epoch_start = self.store.tier_stats();
        let shard_epoch_start = self.store.shard_stats();
        let nvme_epoch_start = self.store.nvme_stats();
        // Per-link wire-byte accumulator for the power model, keyed by
        // topology kind (DESIGN.md §15): each link is normalized by its
        // own peak, and the rail tags decide which power term it loads.
        let mut wire_bytes = LinkBytes::default();
        // Near-memory reduction busy seconds (`--aggregate-pushdown`):
        // feeds the power model's near-memory duty cycle.
        let mut near_mem_busy_s = 0.0f64;
        // Per-step resource demands for the overlap engine.
        let mut demands: Vec<ResourceDemand> = Vec::with_capacity(seeds.len());

        let pipe = {
            let store = &self.store;
            let sampler = &sampler;
            let seeds = &seeds;
            let rng = Mutex::new(rng);
            let artifact = self.artifact.as_ref();
            let mut state = self.state.as_mut();
            let mut native = self.native.as_mut();
            let report = &mut report;
            let demands = &mut demands;
            let wire_bytes = &mut wire_bytes;
            let near_mem_busy_s = &mut near_mem_busy_s;
            run_pipeline(
                seeds.len() as u64,
                queue_depth,
                // --- sample (worker thread; locks the epoch RNG, and the
                // single sampler thread visits steps in order, so the RNG
                // stream matches the serial loop exactly) ---
                |i| {
                    let mb = sampler.sample(&seeds[i as usize], &mut rng.lock().unwrap());
                    debug_assert!(mb.validate().is_ok());
                    Ok(mb)
                },
                // --- gather + simulated transfer costing (worker thread;
                // FIFO order keeps tier/shard/storage cache accounting
                // step-granular like the serial loop).  With dedup on,
                // the batch is compacted to its unique node set first:
                // every store prices the deduplicated stream and a
                // scatter rebuilds the requested layout bitwise
                // identically (DESIGN.md §10) ---
                |mb| {
                    // Push-down prices the step *before* the physical
                    // gather: `pushdown_cost` is read-only, so the tier /
                    // shard / storage classification sees the same
                    // pre-batch state the raw costing below will record
                    // against (DESIGN.md §14).
                    let pushed = if pushdown_on {
                        Some(AggregatePlan::build(&mb)?)
                    } else {
                        None
                    };
                    let pd = match &pushed {
                        Some(plan) => Some(store.pushdown_cost(plan, dedup_on)?),
                        None => None,
                    };
                    let mut x0 = vec![0f32; mb.gather_rows() * dim];
                    let (raw_cost, unique) = if dedup_on {
                        let plan = mb.compact();
                        let cost = store.gather_planned(&plan, &mut x0)?;
                        (cost, plan.unique_rows() as u64)
                    } else {
                        let cost = store.gather_into(&mb.src_nodes, &mut x0)?;
                        (cost, mb.gather_rows() as u64)
                    };
                    if let Some(plan) = &pushed {
                        // Measured counterpart of the near-memory work:
                        // the pinned-order reduction over the gathered
                        // rows — by construction bitwise identical to
                        // what the tiers' combined partials produce, so
                        // numerics never depend on the knob.
                        let mut agg = vec![0f32; plan.n_dst() * dim];
                        let mut counts = vec![0u32; plan.n_dst()];
                        plan.aggregate_gathered(&x0, dim, &mut agg, &mut counts)?;
                        debug_assert_eq!(
                            counts.iter().map(|&c| c as usize).sum::<usize>(),
                            plan.neighbor_rows()
                        );
                    }
                    // When push-down is on the epoch pays the pushed-down
                    // cost; the raw cost rides along for the reduction
                    // factor (its link bytes are what `--no-pushdown`
                    // would have reported).
                    match pd {
                        Some(p) => Ok((mb, x0, p.cost, unique, Some((p, raw_cost.bytes_on_link)))),
                        None => Ok((mb, x0, raw_cost, unique, None)),
                    }
                },
                // --- train (calling thread, FIFO) ---
                |(mb, x0, cost, unique_rows, pushed)| {
                    let requested_rows = mb.gather_rows() as u64;
                    report.dedup.requested_rows += requested_rows;
                    report.dedup.unique_rows += unique_rows;
                    report.dedup.bytes_saved += (requested_rows - unique_rows) * row_bytes;
                    report.breakdown_sim.transfer_s += cost.time_s;
                    report.cpu_gather_s += cost.cpu_time_s;
                    report.bytes_on_link += cost.bytes_on_link;
                    wire_bytes.add(ResourceKind::HostLink, cost.split.host_bytes_on_link);
                    wire_bytes.add(ResourceKind::PeerLink, cost.split.peer_bytes_on_link);
                    wire_bytes.add(ResourceKind::StorageLink, cost.split.storage_bytes_on_link);
                    wire_bytes.add(ResourceKind::NetLink, cost.split.net_bytes_on_link);
                    report.requests += cost.requests;
                    demands.push(cost.demand());
                    if let Some((pd, raw_bytes)) = pushed {
                        report.pushdown.raw_bytes_on_link += raw_bytes;
                        report.pushdown.pushed_bytes_on_link += pd.cost.bytes_on_link;
                        report.pushdown.agg_bytes_on_link += pd.agg_bytes_on_link;
                        report.pushdown.dst_rows += pd.dst_rows;
                        report.pushdown.neighbor_rows += pd.neighbor_rows;
                        report.pushdown.agg_rows += pd.agg_rows;
                        report.pushdown.near_mem_flops += pd.near_mem_flops;
                        report.pushdown.near_mem_s += pd.near_mem_s;
                        *near_mem_busy_s += pd.near_mem_s;
                    }

                    if let (Some(artifact), Some(state)) = (artifact, state.as_deref_mut()) {
                        let t = Timer::start();
                        // x0 is an owned per-step buffer now (the gather
                        // stage allocates it), so it moves into the batch —
                        // the old serial loop cloned a reused buffer here.
                        let batch = StepBatch {
                            x0,
                            nbrs: mb.layers.iter().map(|l| l.nbr.clone()).collect(),
                            masks: mb.layers.iter().map(|l| l.mask.clone()).collect(),
                            labels: mb.labels.clone(),
                        };
                        let assemble_s = t.elapsed_s();
                        report.breakdown_measured.other_s += assemble_s;
                        let metrics = state.step(artifact, &batch)?;
                        report.breakdown_measured.train_s += metrics.exec_s;
                        report.losses.push(metrics.loss);
                        report.accs.push(metrics.acc);
                    } else if let Some(native) = native.as_deref_mut() {
                        // Native backend: softmax regression over the root
                        // rows (the prefix of x0) — deterministic,
                        // mode-invariant.
                        let metrics = native.step(&x0, &mb.labels)?;
                        report.breakdown_measured.train_s += metrics.exec_s;
                        report.losses.push(metrics.loss);
                        report.accs.push(metrics.acc);
                    }
                    report.steps += 1;
                    Ok(())
                },
            )?
        };
        report.breakdown_measured.sample_s = pipe.stages.sample_s;
        report.breakdown_measured.transfer_s = pipe.stages.gather_s;
        report.pipeline = pipe;

        // --- simulated-testbed sampling + training (per-step constants) ---
        let (sample_step_s, train_step_s) = if let Some(cm) = &self.compute {
            (cm.sample_step_s(&self.cfg.system), cm.train_step_s(&self.cfg.system))
        } else {
            // skip_train: estimate from the sampler shape directly
            let slots: u64 = self
                .cfg
                .fanouts
                .iter()
                .rev()
                .scan(self.cfg.batch, |n_dst, &f| {
                    let s = (*n_dst * f) as u64;
                    *n_dst *= 1 + f;
                    Some(s)
                })
                .sum();
            (slots as f64 * self.cfg.system.sample_s_per_edge, 0.0)
        };
        report.breakdown_sim.sample_s = sample_step_s * report.steps as f64;
        report.breakdown_sim.train_s = train_step_s * report.steps as f64;
        report.breakdown_sim.other_s = 0.02 * report.breakdown_sim.total_s();

        // --- overlap engine: schedule the epoch's step DAGs onto the
        // shared resources (DESIGN.md §9).  Depth 0 returns the additive
        // serial breakdown above bit-exactly.
        report.overlap = schedule_epoch(
            &demands,
            &OverlapParams {
                sample_step_s,
                train_step_s,
                other_s: report.breakdown_sim.other_s,
                serial_s: report.breakdown_sim.total_s(),
                prefetch_depth: self.cfg.effective_prefetch_depth(),
                sampler_lanes: self.cfg.sampler_workers.max(1),
            },
        );

        // Topology (DESIGN.md §6): every simulated GPU owns its own PCIe
        // link to host memory and its own NVLink ingress budget, and the
        // link-byte accumulators sum across all GPUs — so both are
        // normalized to the average per-link load before the power model
        // divides by a single link's peak.  Only `Sharded` mode actually
        // instantiates multiple GPUs; a stray `--num-gpus` with any other
        // mode must not deflate that mode's single-link utilization.
        let n_links = if self.cfg.mode == AccessMode::Sharded {
            u64::from(self.cfg.num_gpus.max(1))
        } else {
            1
        };
        let mut wire = LinkBytes::default();
        wire.set(
            ResourceKind::HostLink,
            wire_bytes.get(ResourceKind::HostLink) / n_links,
        );
        wire.set(
            ResourceKind::PeerLink,
            wire_bytes.get(ResourceKind::PeerLink) / n_links,
        );
        // One SSD and one NIC per host regardless of GPU count (only
        // `Nvme` mode produces storage traffic; network bytes leave
        // through the host's single NIC).
        wire.set(
            ResourceKind::StorageLink,
            wire_bytes.get(ResourceKind::StorageLink),
        );
        wire.set(ResourceKind::NetLink, wire_bytes.get(ResourceKind::NetLink));
        report.power = epoch_power(
            &self.cfg.system,
            &report.breakdown_sim,
            report.cpu_gather_s,
            &wire,
            near_mem_busy_s,
        );
        report.tier = self.store.tier_stats().map(|now| match &tier_epoch_start {
            Some(start) => now.since(start),
            None => now,
        });
        report.shard = self.store.shard_stats().map(|now| match &shard_epoch_start {
            Some(start) => now.since(start),
            None => now,
        });
        report.nvme = self.store.nvme_stats().map(|now| match &nvme_epoch_start {
            Some(start) => now.since(start),
            None => now,
        });
        Ok(report)
    }

    /// Switch access mode in place (rebuilds the feature store only).
    pub fn set_mode(&mut self, mode: AccessMode) -> Result<()> {
        if mode == self.cfg.mode {
            return Ok(());
        }
        self.cfg.mode = mode;
        self.store = build_store(&self.cfg, &self.graph, &self.preset)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(mode: AccessMode) -> RunConfig {
        RunConfig {
            dataset: "product".into(),
            mode,
            scale: 2048,
            feature_budget: 8 << 20,
            steps_per_epoch: 3,
            skip_train: true, // unit tests stay PJRT-free; integration covers it
            ..RunConfig::default()
        }
    }

    #[test]
    fn dedup_cuts_transfer_without_changing_the_request_count() {
        // Same config, dedup on vs off: the epoch requests the same rows,
        // fetches strictly fewer, and pays strictly fewer link bytes.
        let mut on = Trainer::new(small_cfg(AccessMode::UnifiedAligned)).unwrap();
        let r_on = on.run_epoch().unwrap();
        let mut cfg = small_cfg(AccessMode::UnifiedAligned);
        cfg.dedup = false;
        let mut off = Trainer::new(cfg).unwrap();
        let r_off = off.run_epoch().unwrap();

        assert!(r_on.dedup.enabled);
        assert!(!r_off.dedup.enabled);
        assert_eq!(r_on.dedup.requested_rows, r_off.dedup.requested_rows);
        assert_eq!(r_off.dedup.unique_rows, r_off.dedup.requested_rows);
        assert_eq!(r_off.dedup.bytes_saved, 0);
        assert!(
            r_on.dedup.unique_rows < r_on.dedup.requested_rows,
            "overlapping neighborhoods must deduplicate"
        );
        assert!(r_on.dedup.ratio() > 1.0);
        assert!(r_on.dedup.bytes_saved > 0);
        assert!(r_on.bytes_on_link < r_off.bytes_on_link);
        assert!(r_on.breakdown_sim.transfer_s < r_off.breakdown_sim.transfer_s);
    }

    #[test]
    fn empty_fanouts_rejected_at_build_not_panicking() {
        // Regression: `fanouts = []` used to panic deep in the sampler
        // (`layers.last().unwrap()`); programmatic configs bypass the CLI
        // validation, so Trainer::new must validate itself.
        let mut cfg = small_cfg(AccessMode::UnifiedAligned);
        cfg.fanouts = vec![];
        match Trainer::new(cfg) {
            Err(Error::Config(msg)) => {
                assert!(msg.contains("fanouts must be non-empty"), "unhelpful: {msg}")
            }
            Err(e) => panic!("expected Config error, got {e}"),
            Ok(_) => panic!("empty fanouts accepted"),
        }
    }

    #[test]
    fn pushdown_cuts_link_bytes_and_keeps_numerics() {
        // The tentpole at the epoch level: same seeds, pushdown on vs off
        // — the on-run's raw costing reproduces the off-run's bytes, the
        // pushed-down epoch pays strictly less, the near-memory engine
        // heats up, and the loss trajectory is bitwise unchanged.
        let mut off_cfg = small_cfg(AccessMode::UnifiedAligned);
        off_cfg.skip_train = false;
        off_cfg.backend = Backend::Native;
        off_cfg.artifacts_dir = "definitely/not/a/real/dir".into();
        let mut on_cfg = off_cfg.clone();
        on_cfg.aggregate_pushdown = true;
        let r_on = Trainer::new(on_cfg).unwrap().run_epoch().unwrap();
        let r_off = Trainer::new(off_cfg).unwrap().run_epoch().unwrap();

        assert!(r_on.pushdown.enabled);
        assert!(!r_off.pushdown.enabled);
        assert_eq!(r_off.pushdown.raw_bytes_on_link, 0, "off-run reports nothing");
        assert_eq!(r_on.pushdown.raw_bytes_on_link, r_off.bytes_on_link);
        assert_eq!(r_on.bytes_on_link, r_on.pushdown.pushed_bytes_on_link);
        assert!(
            r_on.bytes_on_link < r_off.bytes_on_link,
            "pushdown {} !< raw {}",
            r_on.bytes_on_link,
            r_off.bytes_on_link
        );
        assert!(r_on.pushdown.reduction() > 1.0);
        assert!(r_on.pushdown.agg_rows > 0);
        assert!(r_on.pushdown.near_mem_flops > 0);
        assert!(r_on.pushdown.near_mem_s > 0.0);
        assert!(r_on.power.near_mem_util > 0.0);
        assert_eq!(r_off.power.near_mem_util, 0.0);
        assert_eq!(
            r_on.losses.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
            r_off.losses.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
            "numerics must not depend on the pushdown knob"
        );
    }

    #[test]
    fn oversized_batch_is_rejected_at_build_time() {
        // `epoch_seeds` would silently yield zero batches (drop_last) and
        // the per-epoch averages would divide by an empty step list.
        let mut cfg = small_cfg(AccessMode::UnifiedAligned);
        cfg.batch = 1 << 20; // far beyond the scaled graph's node count
        match Trainer::new(cfg) {
            Err(Error::Config(msg)) => {
                assert!(msg.contains("zero batches"), "unhelpful message: {msg}")
            }
            Err(e) => panic!("expected Config error, got {e}"),
            Ok(_) => panic!("oversized batch accepted"),
        }
    }

    #[test]
    fn classes_override_threads_through_to_labels() {
        let mut cfg = small_cfg(AccessMode::UnifiedAligned);
        cfg.classes = Some(3);
        cfg.skip_train = false;
        cfg.backend = Backend::Native;
        cfg.artifacts_dir = "definitely/not/a/real/dir".into();
        let mut t = Trainer::new(cfg).unwrap();
        let r = t.run_epoch().unwrap();
        assert_eq!(r.losses.len(), 3);
        assert!(r.losses.iter().all(|l| l.is_finite()));
        for node in 0..50u32 {
            let l = t.store().label(node);
            assert!((0..3).contains(&l), "label {l} outside --classes 3");
        }
    }

    #[test]
    fn epoch_accounting_pyd_beats_py() {
        let mut t = Trainer::new(small_cfg(AccessMode::CpuGather)).unwrap();
        let py = t.run_epoch().unwrap();
        t.set_mode(AccessMode::UnifiedAligned).unwrap();
        let pyd = t.run_epoch().unwrap();
        assert_eq!(py.steps, 3);
        assert!(py.breakdown_sim.transfer_s > pyd.breakdown_sim.transfer_s);
        assert!(py.cpu_gather_s > 0.0);
        assert_eq!(pyd.cpu_gather_s, 0.0);
    }

    #[test]
    fn measured_side_really_moves_bytes() {
        let mut t = Trainer::new(small_cfg(AccessMode::UnifiedAligned)).unwrap();
        let r = t.run_epoch().unwrap();
        assert!(r.breakdown_measured.sample_s > 0.0);
        assert!(r.breakdown_measured.transfer_s > 0.0);
        assert!(r.bytes_on_link > 0);
    }

    #[test]
    fn unknown_dataset_rejected() {
        let mut cfg = small_cfg(AccessMode::CpuGather);
        cfg.dataset = "imagenet".into();
        assert!(Trainer::new(cfg).is_err());
    }

    #[test]
    fn tiered_epoch_reports_hits_and_beats_unified() {
        let mut t = Trainer::new(small_cfg(AccessMode::UnifiedAligned)).unwrap();
        let ua = t.run_epoch().unwrap();
        assert!(ua.tier.is_none(), "tier stats must be Tiered-only");
        t.set_mode(AccessMode::Tiered).unwrap();
        let tiered = t.run_epoch().unwrap();
        let stats = tiered.tier.expect("tiered mode reports tier stats");
        assert!(stats.hits > 0, "degree-ranked hot set never hit");
        assert!(stats.misses > 0, "a 25% hot set cannot serve everything");
        assert!(stats.hot_bytes <= stats.capacity_bytes);
        assert!(
            tiered.breakdown_sim.transfer_s < ua.breakdown_sim.transfer_s,
            "tiered {} !< unified {}",
            tiered.breakdown_sim.transfer_s,
            ua.breakdown_sim.transfer_s
        );
    }

    // The sharded N=1-degenerates-to-tiered contract and the per-GPU
    // epoch splits are covered one layer up (`tests/e2e_train.rs`) and
    // one layer down (`featurestore::sharded`/`store` unit tests,
    // `tests/sharded_properties.rs`) — no trainer-level duplicate.

    #[test]
    fn nvme_epoch_reports_tier_splits_and_pays_for_spilling() {
        let mut resident = small_cfg(AccessMode::Nvme);
        resident.host_frac = 1.0;
        let r_res = Trainer::new(resident).unwrap().run_epoch().unwrap();
        assert!(r_res.nvme.is_some(), "nvme mode reports storage stats");
        assert_eq!(r_res.nvme.unwrap().storage_rows, 0, "host_frac 1 never spills");
        assert_eq!(r_res.power.storage_util, 0.0);

        let mut spilled = small_cfg(AccessMode::Nvme);
        spilled.host_frac = 0.1;
        let r_sp = Trainer::new(spilled).unwrap().run_epoch().unwrap();
        let stats = r_sp.nvme.expect("nvme epoch reports storage stats");
        assert!(stats.storage_rows > 0, "10% host tier must spill");
        assert!(stats.ios > 0);
        assert!(stats.amplification() >= 1.0);
        assert!(r_sp.power.storage_util > 0.0);
        assert!(
            r_sp.breakdown_sim.transfer_s > r_res.breakdown_sim.transfer_s,
            "spilling must cost transfer time: {} !> {}",
            r_sp.breakdown_sim.transfer_s,
            r_res.breakdown_sim.transfer_s
        );
        // Storage reads are GPU-initiated: still no CPU on the path.
        assert_eq!(r_sp.cpu_gather_s, 0.0);
    }

    #[test]
    fn depth_zero_overlap_is_the_serial_breakdown_bit_exactly() {
        for mode in AccessMode::all() {
            let mut cfg = small_cfg(mode);
            cfg.prefetch_depth = 0;
            let r = Trainer::new(cfg).unwrap().run_epoch().unwrap();
            assert_eq!(
                r.overlap.overlapped_s,
                r.breakdown_sim.total_s(),
                "{mode:?}: depth 0 must anchor to the serial sum"
            );
            assert_eq!(r.overlap.serial_s, r.breakdown_sim.total_s(), "{mode:?}");
            assert_eq!(r.overlap.prefetch_depth, 0);
        }
    }

    #[test]
    fn no_overlap_flag_forces_the_serial_timeline() {
        let mut cfg = small_cfg(AccessMode::UnifiedAligned);
        cfg.prefetch_depth = 8;
        cfg.no_overlap = true;
        let r = Trainer::new(cfg).unwrap().run_epoch().unwrap();
        assert_eq!(r.overlap.overlapped_s, r.breakdown_sim.total_s());
    }

    #[test]
    fn overlapped_epoch_sits_between_the_structural_bounds() {
        let mut cfg = small_cfg(AccessMode::UnifiedAligned);
        cfg.prefetch_depth = 4;
        let r = Trainer::new(cfg).unwrap().run_epoch().unwrap();
        let o = &r.overlap;
        assert!(
            o.overlapped_s < o.serial_s,
            "depth 4 must hide sampling under the zero-copy transfer: {} !< {}",
            o.overlapped_s,
            o.serial_s
        );
        for kind in crate::coordinator::simclock::ResourceKind::all() {
            assert!(
                o.overlapped_s >= o.busy.get(kind) - 1e-15,
                "{kind:?} busier than the epoch"
            );
        }
        assert!(o.critical.total() > 0.0);
    }

    #[test]
    fn pipelined_epoch_surfaces_queue_stats() {
        let mut t = Trainer::new(small_cfg(AccessMode::UnifiedAligned)).unwrap();
        let r = t.run_epoch().unwrap();
        assert_eq!(r.pipeline.items, r.steps);
        assert!(r.pipeline.wall_s > 0.0);
        assert!(r.pipeline.stages.sample_s > 0.0);
        assert!(r.pipeline.stages.gather_s > 0.0);
    }

    #[test]
    fn native_backend_trains_without_artifacts() {
        let mut cfg = small_cfg(AccessMode::UnifiedAligned);
        cfg.skip_train = false;
        cfg.backend = Backend::Native;
        cfg.artifacts_dir = "definitely/not/a/real/dir".into();
        let mut t = Trainer::new(cfg).unwrap();
        let r = t.run_epoch().unwrap();
        assert_eq!(r.losses.len(), 3);
        assert!(r.losses.iter().all(|l| l.is_finite()));
        assert!(r.accs.iter().all(|a| (0.0..=1.0).contains(a)));
        assert!(r.breakdown_measured.train_s > 0.0);
    }

    #[test]
    fn auto_backend_falls_back_to_native_without_artifacts() {
        let mut cfg = small_cfg(AccessMode::UnifiedAligned);
        cfg.skip_train = false;
        cfg.backend = Backend::Auto;
        cfg.artifacts_dir = "definitely/not/a/real/dir".into();
        let mut t = Trainer::new(cfg).unwrap();
        assert!(!t.run_epoch().unwrap().losses.is_empty());
    }

    #[test]
    fn auto_backend_falls_back_when_this_runs_artifact_is_missing() {
        // A manifest that exists but lacks this run's artifact must not
        // commit Auto to the PJRT path — the native fallback trains fine.
        let dir = std::env::temp_dir().join("ptdirect_auto_fallback_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "artifact sage_other\nfile sage_other.hlo.txt\nkind train\nend\n",
        )
        .unwrap();
        let mut cfg = small_cfg(AccessMode::UnifiedAligned);
        cfg.skip_train = false;
        cfg.backend = Backend::Auto;
        cfg.artifacts_dir = dir.to_str().unwrap().into();
        let mut t = Trainer::new(cfg).unwrap();
        assert!(!t.run_epoch().unwrap().losses.is_empty());
    }

    #[test]
    fn pjrt_backend_requires_artifacts() {
        let mut cfg = small_cfg(AccessMode::UnifiedAligned);
        cfg.skip_train = false;
        cfg.backend = Backend::Pjrt;
        cfg.artifacts_dir = "definitely/not/a/real/dir".into();
        assert!(Trainer::new(cfg).is_err());
    }
}

//! Whole-system power model — paper Fig. 9.
//!
//! The paper measures wall power at the electricity meter and attributes
//! the PyD savings to reduced CPU utilization during the data-loading
//! phases.  We map an epoch's time breakdown to average device
//! utilizations through per-phase activity weights, then through the
//! affine [`crate::config::PowerProfile`].
//!
//! Activity weights (fraction of the package kept busy while a phase runs):
//! sampling is multithreaded graph traversal (~0.7 of package), the
//! baseline's gather hammers the memory controllers with many threads
//! (~0.95 — the paper's Fig. 3 shows CPU util far above one core), other
//! host work idles most of the package (~0.15).  GPU training keeps the
//! board near-fully busy; zero-copy transfers burn only the copy engines.
//!
//! Link power is topology-driven (DESIGN.md §15): the epoch's wire bytes
//! arrive as a per-link [`LinkBytes`] map, each registered link's duty
//! cycle is its bytes over its own peak bandwidth, and the duty cycles
//! sum onto the link's power rail — PCIe, NVLink, and the NIC share the
//! host I/O-complex term ([`crate::config::PowerProfile::io_max_w`]), the
//! SSD draws its own ([`crate::config::PowerProfile::ssd_max_w`]).  A new
//! link enters the power model by joining the topology registry, not by
//! growing this function's signature again.

use crate::config::SystemProfile;
use crate::coordinator::trainer::Breakdown;
use crate::interconnect::{LinkBytes, LinkShare, PowerRail, Topology};

/// Per-phase package-utilization weights.
pub const CPU_W_SAMPLE: f64 = 0.70;
pub const CPU_W_GATHER: f64 = 0.95;
pub const CPU_W_OTHER: f64 = 0.15;
pub const GPU_W_TRAIN: f64 = 0.90;
pub const GPU_W_TRANSFER: f64 = 0.20;
/// DGL-style dataloaders run several worker processes that stay hot beyond
/// the critical-path sampling/gather time (prefetching the next batches,
/// spinning in the queue) — the paper's Fig. 3 shows CPU utilization far
/// above what serial-phase accounting would give.  The multiplier applies
/// to the CPU-busy numerator of both modes (PyD still samples on CPU).
pub const WORKER_OVERSUBSCRIPTION: f64 = 1.5;

/// Power summary for one epoch.
#[derive(Clone, Copy, Debug, Default)]
pub struct PowerReport {
    pub cpu_util: f64,
    pub gpu_util: f64,
    /// Summed duty cycle of the I/O-rail links (PCIe + NVLink + NIC).
    pub io_util: f64,
    /// NVMe read utilization (the `Nvme` storage tier; zero elsewhere).
    pub storage_util: f64,
    /// Near-memory aggregation-engine utilization (`--aggregate-pushdown`'s
    /// memory-side reduction duty cycle; zero when push-down is off).
    pub near_mem_util: f64,
    /// Per-link duty cycles (each link's bytes over its own peak), the
    /// per-link decomposition of `io_util`/`storage_util`.
    pub link_util: LinkShare,
    pub watts: f64,
    pub energy_j: f64,
}

/// Average power over an epoch with the given breakdown.
///
/// `cpu_gather_s` must be the CPU seconds spent gathering (zero for the
/// GPU-centric modes — that is the entire Fig. 9 story).  `wire` carries
/// the epoch's bytes per transfer link; each registered link of
/// [`Topology::from_sys`] is normalized by its *own* peak bandwidth —
/// charging NVLink peer bytes against PCIe bandwidth would saturate
/// `io_util` with traffic that never touches the host link.  Peaks are
/// *per-link* budgets (every simulated GPU owns its own PCIe link and
/// NVLink ingress — the topology the sharded timing model prices,
/// DESIGN.md §6), so callers must pass per-link-average byte loads: the
/// trainer divides its fleet-wide host/peer sums by `num_gpus` (1 outside
/// `Sharded` mode).  Storage and network bytes are never divided — the
/// SSD and host 0's NIC are single devices.
///
/// Each link's duty cycle sums onto its power rail: the I/O-complex term
/// for PCIe/NVLink/NIC, the SSD active-power term for NVMe
/// (`PowerProfile::ssd_max_w`, DESIGN.md §8) — the SSD's draw scales with
/// its own read duty cycle, not with the host link's.
///
/// `near_mem_s` is the epoch's memory-side reduction busy time
/// (`--aggregate-pushdown`, DESIGN.md §14; zero otherwise).  Its duty
/// cycle drives the near-memory engine's own affine term
/// (`PowerProfile::near_mem_max_w`) — like the SSD, the engine's draw
/// scales with its own utilization, not the CPU's or GPU's.
pub fn epoch_power(
    sys: &SystemProfile,
    b: &Breakdown,
    cpu_gather_s: f64,
    wire: &LinkBytes,
    near_mem_s: f64,
) -> PowerReport {
    let epoch = b.total_s().max(1e-12);
    let cpu_util = ((b.sample_s * CPU_W_SAMPLE + cpu_gather_s * CPU_W_GATHER)
        * WORKER_OVERSUBSCRIPTION
        / epoch
        + b.other_s * CPU_W_OTHER / epoch)
        .clamp(0.0, 1.0);
    let gpu_util = ((b.train_s * GPU_W_TRAIN + b.transfer_s * GPU_W_TRANSFER) / epoch)
        .clamp(0.0, 1.0);
    let mut link_util = LinkShare::default();
    let mut io_util = 0.0;
    let mut storage_util = 0.0;
    for l in Topology::from_sys(sys).links() {
        let duty = wire.get(l.kind) as f64 / epoch / l.peak_bw;
        link_util.set(l.kind, duty.clamp(0.0, 1.0));
        match l.rail {
            Some(PowerRail::Io) => io_util += duty,
            Some(PowerRail::Storage) => storage_util += duty,
            None => {}
        }
    }
    let io_util = io_util.clamp(0.0, 1.0);
    let storage_util = storage_util.clamp(0.0, 1.0);
    let near_mem_util = (near_mem_s / epoch).clamp(0.0, 1.0);
    let watts = sys.power.watts(cpu_util, gpu_util, io_util, storage_util)
        + near_mem_util * sys.power.near_mem_max_w;
    PowerReport {
        cpu_util,
        gpu_util,
        io_util,
        storage_util,
        near_mem_util,
        link_util,
        watts,
        energy_j: watts * epoch,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interconnect::ResourceKind;

    fn breakdown(sample: f64, transfer: f64, train: f64, other: f64) -> Breakdown {
        Breakdown {
            sample_s: sample,
            transfer_s: transfer,
            train_s: train,
            other_s: other,
        }
    }

    fn wire(host: u64, peer: u64, storage: u64) -> LinkBytes {
        let mut w = LinkBytes::default();
        w.set(ResourceKind::HostLink, host);
        w.set(ResourceKind::PeerLink, peer);
        w.set(ResourceKind::StorageLink, storage);
        w
    }

    #[test]
    fn removing_cpu_gather_lowers_power() {
        let sys = SystemProfile::system1();
        // Py: 10s epoch with 3s CPU gather inside the 4s transfer phase.
        let py = breakdown(2.0, 4.0, 3.5, 0.5);
        let p_py = epoch_power(&sys, &py, 3.0, &wire(40 << 30, 0, 0), 0.0);
        // PyD: gather gone, transfer shrinks, same train.
        let pyd = breakdown(2.0, 1.8, 3.5, 0.5);
        let p_pyd = epoch_power(&sys, &pyd, 0.0, &wire(42 << 30, 0, 0), 0.0);
        assert!(p_pyd.watts < p_py.watts);
        let saving = 1.0 - p_pyd.watts / p_py.watts;
        assert!(
            saving > 0.05 && saving < 0.30,
            "saving {saving} (paper band 12.4%-17.5%)"
        );
    }

    #[test]
    fn idle_epoch_is_idle_power() {
        let sys = SystemProfile::system1();
        let p = epoch_power(&sys, &breakdown(0.0, 0.0, 0.0, 1.0), 0.0, &LinkBytes::default(), 0.0);
        assert!(p.watts < sys.power.idle_w + 0.2 * sys.power.cpu_max_w);
    }

    #[test]
    fn utils_clamped() {
        let sys = SystemProfile::system2();
        let mut w = wire(u64::MAX, u64::MAX, u64::MAX);
        w.set(ResourceKind::NetLink, u64::MAX);
        let p = epoch_power(&sys, &breakdown(100.0, 100.0, 100.0, 0.0), 300.0, &w, f64::MAX);
        assert!(p.cpu_util <= 1.0 && p.gpu_util <= 1.0 && p.io_util <= 1.0);
        assert!(p.storage_util <= 1.0);
        assert!(p.near_mem_util <= 1.0);
        for kind in ResourceKind::all() {
            assert!(p.link_util.get(kind) <= 1.0);
        }
    }

    #[test]
    fn near_mem_seconds_drive_their_own_power_term() {
        // Push-down's reduction time heats the near-memory engine only:
        // every other utilization is untouched, and the added draw is
        // bounded by the engine's (deliberately modest) max wattage.
        let sys = SystemProfile::system1();
        let b = breakdown(1.0, 1.0, 1.0, 0.1);
        let off = epoch_power(&sys, &b, 0.0, &wire(8 << 30, 0, 0), 0.0);
        let on = epoch_power(&sys, &b, 0.0, &wire(8 << 30, 0, 0), 0.5);
        assert_eq!(off.near_mem_util, 0.0);
        assert!(on.near_mem_util > 0.0);
        assert_eq!(on.cpu_util, off.cpu_util);
        assert_eq!(on.gpu_util, off.gpu_util);
        assert_eq!(on.io_util, off.io_util);
        assert_eq!(on.storage_util, off.storage_util);
        assert!(on.watts > off.watts);
        assert!(
            on.watts - off.watts <= sys.power.near_mem_max_w + 1e-9,
            "near-mem term bounded by its max draw"
        );
    }

    #[test]
    fn peer_bytes_load_nvlink_not_pcie() {
        // The same byte volume costs less io_util as NVLink peer traffic
        // than as host PCIe traffic (NVLink peak is several times higher).
        let sys = SystemProfile::system1();
        let b = breakdown(1.0, 1.0, 1.0, 0.1);
        let as_host = epoch_power(&sys, &b, 0.0, &wire(8 << 30, 0, 0), 0.0);
        let as_peer = epoch_power(&sys, &b, 0.0, &wire(0, 8 << 30, 0), 0.0);
        assert!(as_peer.io_util < as_host.io_util);
        assert!(as_peer.watts <= as_host.watts);
        // The per-link decomposition attributes each load to its lane.
        assert!(as_host.link_util.get(ResourceKind::HostLink) > 0.0);
        assert_eq!(as_host.link_util.get(ResourceKind::PeerLink), 0.0);
        assert!(as_peer.link_util.get(ResourceKind::PeerLink) > 0.0);
        assert_eq!(as_peer.link_util.get(ResourceKind::HostLink), 0.0);
    }

    #[test]
    fn storage_bytes_drive_ssd_power_not_io_util() {
        // Block reads heat the SSD term, not the PCIe/NVLink I/O term —
        // and a storage-quiet epoch pays no SSD active power at all.
        let sys = SystemProfile::system1();
        let b = breakdown(1.0, 1.0, 1.0, 0.1);
        let quiet = epoch_power(&sys, &b, 0.0, &wire(0, 0, 0), 0.0);
        let busy = epoch_power(&sys, &b, 0.0, &wire(0, 0, 4 << 30), 0.0);
        assert_eq!(quiet.storage_util, 0.0);
        assert!(busy.storage_util > 0.0);
        assert_eq!(busy.io_util, quiet.io_util);
        assert!(busy.watts > quiet.watts);
        assert!(
            busy.watts - quiet.watts <= sys.power.ssd_max_w + 1e-9,
            "SSD term bounded by its max draw"
        );
    }

    #[test]
    fn net_bytes_load_the_io_rail_at_nic_bandwidth() {
        // Remote-fetch traffic heats the host I/O complex (the NIC shares
        // the rail with PCIe/NVLink), normalized by the NIC's own peak —
        // the same byte volume costs *more* duty than over NVLink, since
        // the NIC is the slower link.
        let sys = SystemProfile::system1();
        let b = breakdown(1.0, 1.0, 1.0, 0.1);
        let mut w = LinkBytes::default();
        w.set(ResourceKind::NetLink, 4 << 30);
        let with_net = epoch_power(&sys, &b, 0.0, &w, 0.0);
        let quiet = epoch_power(&sys, &b, 0.0, &LinkBytes::default(), 0.0);
        assert!(with_net.io_util > quiet.io_util);
        assert_eq!(with_net.storage_util, quiet.storage_util);
        assert!(with_net.link_util.get(ResourceKind::NetLink) > 0.0);
        let mut p = LinkBytes::default();
        p.set(ResourceKind::PeerLink, 4 << 30);
        let as_peer = epoch_power(&sys, &b, 0.0, &p, 0.0);
        assert!(with_net.io_util > as_peer.io_util);
        // A net-quiet epoch's report is bitwise free of the new lane.
        assert_eq!(quiet.link_util.get(ResourceKind::NetLink), 0.0);
    }
}

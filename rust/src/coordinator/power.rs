//! Whole-system power model — paper Fig. 9.
//!
//! The paper measures wall power at the electricity meter and attributes
//! the PyD savings to reduced CPU utilization during the data-loading
//! phases.  We map an epoch's time breakdown to average device
//! utilizations through per-phase activity weights, then through the
//! affine [`crate::config::PowerProfile`].
//!
//! Activity weights (fraction of the package kept busy while a phase runs):
//! sampling is multithreaded graph traversal (~0.7 of package), the
//! baseline's gather hammers the memory controllers with many threads
//! (~0.95 — the paper's Fig. 3 shows CPU util far above one core), other
//! host work idles most of the package (~0.15).  GPU training keeps the
//! board near-fully busy; zero-copy transfers burn only the copy engines.

use crate::config::SystemProfile;
use crate::coordinator::trainer::Breakdown;

/// Per-phase package-utilization weights.
pub const CPU_W_SAMPLE: f64 = 0.70;
pub const CPU_W_GATHER: f64 = 0.95;
pub const CPU_W_OTHER: f64 = 0.15;
pub const GPU_W_TRAIN: f64 = 0.90;
pub const GPU_W_TRANSFER: f64 = 0.20;
/// DGL-style dataloaders run several worker processes that stay hot beyond
/// the critical-path sampling/gather time (prefetching the next batches,
/// spinning in the queue) — the paper's Fig. 3 shows CPU utilization far
/// above what serial-phase accounting would give.  The multiplier applies
/// to the CPU-busy numerator of both modes (PyD still samples on CPU).
pub const WORKER_OVERSUBSCRIPTION: f64 = 1.5;

/// Power summary for one epoch.
#[derive(Clone, Copy, Debug, Default)]
pub struct PowerReport {
    pub cpu_util: f64,
    pub gpu_util: f64,
    pub io_util: f64,
    /// NVMe read utilization (the `Nvme` storage tier; zero elsewhere).
    pub storage_util: f64,
    /// Near-memory aggregation-engine utilization (`--aggregate-pushdown`'s
    /// memory-side reduction duty cycle; zero when push-down is off).
    pub near_mem_util: f64,
    pub watts: f64,
    pub energy_j: f64,
}

/// Average power over an epoch with the given breakdown.
///
/// `cpu_gather_s` must be the CPU seconds spent gathering (zero for the
/// GPU-centric modes — that is the entire Fig. 9 story).  Link bytes are
/// split per link: `host_bytes_on_link` is normalized by the PCIe peak,
/// `peer_bytes_on_link` (the `Sharded` mode's NVLink traffic, zero
/// everywhere else) by the much larger NVLink peak — charging peer bytes
/// against PCIe bandwidth would saturate `io_util` with traffic that
/// never touches the host link.  Both peaks are *per-link* budgets (every
/// simulated GPU owns its own PCIe link and NVLink ingress — the topology
/// the sharded timing model prices, DESIGN.md §6), so callers must pass
/// per-link-average byte loads: the trainer divides its fleet-wide sums
/// by `num_gpus` (1 outside `Sharded` mode).
///
/// `storage_bytes_on_link` (the `Nvme` mode's block-read traffic, zero
/// everywhere else) is normalized by the NVMe peak into its own
/// `storage_util`, which drives the SSD active-power term
/// (`PowerProfile::ssd_max_w`, DESIGN.md §8) rather than the PCIe/NVLink
/// I/O term — the SSD's draw scales with its own read duty cycle, not
/// with the host link's.
///
/// `near_mem_s` is the epoch's memory-side reduction busy time
/// (`--aggregate-pushdown`, DESIGN.md §14; zero otherwise).  Its duty
/// cycle drives the near-memory engine's own affine term
/// (`PowerProfile::near_mem_max_w`) — like the SSD, the engine's draw
/// scales with its own utilization, not the CPU's or GPU's.
pub fn epoch_power(
    sys: &SystemProfile,
    b: &Breakdown,
    cpu_gather_s: f64,
    host_bytes_on_link: u64,
    peer_bytes_on_link: u64,
    storage_bytes_on_link: u64,
    near_mem_s: f64,
) -> PowerReport {
    let epoch = b.total_s().max(1e-12);
    let cpu_util = ((b.sample_s * CPU_W_SAMPLE + cpu_gather_s * CPU_W_GATHER)
        * WORKER_OVERSUBSCRIPTION
        / epoch
        + b.other_s * CPU_W_OTHER / epoch)
        .clamp(0.0, 1.0);
    let gpu_util = ((b.train_s * GPU_W_TRAIN + b.transfer_s * GPU_W_TRANSFER) / epoch)
        .clamp(0.0, 1.0);
    let io_util = (host_bytes_on_link as f64 / epoch / sys.pcie.peak_bw
        + peer_bytes_on_link as f64 / epoch / sys.nvlink.peak_bw)
        .clamp(0.0, 1.0);
    let storage_util =
        (storage_bytes_on_link as f64 / epoch / sys.nvme.peak_bw).clamp(0.0, 1.0);
    let near_mem_util = (near_mem_s / epoch).clamp(0.0, 1.0);
    let watts = sys.power.watts(cpu_util, gpu_util, io_util, storage_util)
        + near_mem_util * sys.power.near_mem_max_w;
    PowerReport {
        cpu_util,
        gpu_util,
        io_util,
        storage_util,
        near_mem_util,
        watts,
        energy_j: watts * epoch,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn breakdown(sample: f64, transfer: f64, train: f64, other: f64) -> Breakdown {
        Breakdown {
            sample_s: sample,
            transfer_s: transfer,
            train_s: train,
            other_s: other,
        }
    }

    #[test]
    fn removing_cpu_gather_lowers_power() {
        let sys = SystemProfile::system1();
        // Py: 10s epoch with 3s CPU gather inside the 4s transfer phase.
        let py = breakdown(2.0, 4.0, 3.5, 0.5);
        let p_py = epoch_power(&sys, &py, 3.0, 40 << 30, 0, 0, 0.0);
        // PyD: gather gone, transfer shrinks, same train.
        let pyd = breakdown(2.0, 1.8, 3.5, 0.5);
        let p_pyd = epoch_power(&sys, &pyd, 0.0, 42 << 30, 0, 0, 0.0);
        assert!(p_pyd.watts < p_py.watts);
        let saving = 1.0 - p_pyd.watts / p_py.watts;
        assert!(
            saving > 0.05 && saving < 0.30,
            "saving {saving} (paper band 12.4%-17.5%)"
        );
    }

    #[test]
    fn idle_epoch_is_idle_power() {
        let sys = SystemProfile::system1();
        let p = epoch_power(&sys, &breakdown(0.0, 0.0, 0.0, 1.0), 0.0, 0, 0, 0, 0.0);
        assert!(p.watts < sys.power.idle_w + 0.2 * sys.power.cpu_max_w);
    }

    #[test]
    fn utils_clamped() {
        let sys = SystemProfile::system2();
        let p = epoch_power(
            &sys,
            &breakdown(100.0, 100.0, 100.0, 0.0),
            300.0,
            u64::MAX,
            u64::MAX,
            u64::MAX,
            f64::MAX,
        );
        assert!(p.cpu_util <= 1.0 && p.gpu_util <= 1.0 && p.io_util <= 1.0);
        assert!(p.storage_util <= 1.0);
        assert!(p.near_mem_util <= 1.0);
    }

    #[test]
    fn near_mem_seconds_drive_their_own_power_term() {
        // Push-down's reduction time heats the near-memory engine only:
        // every other utilization is untouched, and the added draw is
        // bounded by the engine's (deliberately modest) max wattage.
        let sys = SystemProfile::system1();
        let b = breakdown(1.0, 1.0, 1.0, 0.1);
        let off = epoch_power(&sys, &b, 0.0, 8 << 30, 0, 0, 0.0);
        let on = epoch_power(&sys, &b, 0.0, 8 << 30, 0, 0, 0.5);
        assert_eq!(off.near_mem_util, 0.0);
        assert!(on.near_mem_util > 0.0);
        assert_eq!(on.cpu_util, off.cpu_util);
        assert_eq!(on.gpu_util, off.gpu_util);
        assert_eq!(on.io_util, off.io_util);
        assert_eq!(on.storage_util, off.storage_util);
        assert!(on.watts > off.watts);
        assert!(
            on.watts - off.watts <= sys.power.near_mem_max_w + 1e-9,
            "near-mem term bounded by its max draw"
        );
    }

    #[test]
    fn peer_bytes_load_nvlink_not_pcie() {
        // The same byte volume costs less io_util as NVLink peer traffic
        // than as host PCIe traffic (NVLink peak is several times higher).
        let sys = SystemProfile::system1();
        let b = breakdown(1.0, 1.0, 1.0, 0.1);
        let as_host = epoch_power(&sys, &b, 0.0, 8 << 30, 0, 0, 0.0);
        let as_peer = epoch_power(&sys, &b, 0.0, 0, 8 << 30, 0, 0.0);
        assert!(as_peer.io_util < as_host.io_util);
        assert!(as_peer.watts <= as_host.watts);
    }

    #[test]
    fn storage_bytes_drive_ssd_power_not_io_util() {
        // Block reads heat the SSD term, not the PCIe/NVLink I/O term —
        // and a storage-quiet epoch pays no SSD active power at all.
        let sys = SystemProfile::system1();
        let b = breakdown(1.0, 1.0, 1.0, 0.1);
        let quiet = epoch_power(&sys, &b, 0.0, 0, 0, 0, 0.0);
        let busy = epoch_power(&sys, &b, 0.0, 0, 0, 4 << 30, 0.0);
        assert_eq!(quiet.storage_util, 0.0);
        assert!(busy.storage_util > 0.0);
        assert_eq!(busy.io_util, quiet.io_util);
        assert!(busy.watts > quiet.watts);
        assert!(
            busy.watts - quiet.watts <= sys.power.ssd_max_w + 1e-9,
            "SSD term bounded by its max draw"
        );
    }
}

//! Caching memory allocator with allocation recycling — paper §4.4:
//! "A new memory allocator is implemented to govern the memory allocation
//! for all unified tensors. It adapts the allocation recycling mechanism
//! from the PyTorch CUDA allocator to reduce the number of CUDA API
//! invocations."
//!
//! Freed blocks are kept in power-of-two size-class pools and handed back
//! to subsequent allocations of the same class, so steady-state training
//! performs zero backing allocations per step.  Statistics distinguish
//! backing ("cudaMallocManaged-equivalent") calls from recycled hits, which
//! the allocator tests and the perf pass assert on.
//!
//! Blocks are backed by `u64` words, guaranteeing 8-byte alignment so the
//! tensor layer can reinterpret them as `f32`/`i32`/`i64` slices safely.

use std::collections::HashMap;
use std::sync::Mutex;

/// An aligned, size-classed memory block.
#[derive(Debug)]
pub struct Block {
    words: Vec<u64>,
}

impl Block {
    fn new_zeroed(class_bytes: usize) -> Block {
        debug_assert!(class_bytes % 8 == 0);
        Block {
            words: vec![0u64; class_bytes / 8],
        }
    }

    /// Capacity in bytes (the size class, >= the requested size).
    pub fn len_bytes(&self) -> usize {
        self.words.len() * 8
    }

    pub fn as_bytes(&self) -> &[u8] {
        // SAFETY: u64 -> u8 loosens alignment; length covers the same memory.
        unsafe {
            std::slice::from_raw_parts(self.words.as_ptr() as *const u8, self.len_bytes())
        }
    }

    pub fn as_bytes_mut(&mut self) -> &mut [u8] {
        unsafe {
            std::slice::from_raw_parts_mut(
                self.words.as_mut_ptr() as *mut u8,
                self.words.len() * 8,
            )
        }
    }

    pub fn as_f32(&self) -> &[f32] {
        unsafe {
            std::slice::from_raw_parts(self.words.as_ptr() as *const f32, self.len_bytes() / 4)
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        unsafe {
            std::slice::from_raw_parts_mut(
                self.words.as_mut_ptr() as *mut f32,
                self.words.len() * 2,
            )
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        unsafe {
            std::slice::from_raw_parts(self.words.as_ptr() as *const i32, self.len_bytes() / 4)
        }
    }

    pub fn as_i32_mut(&mut self) -> &mut [i32] {
        unsafe {
            std::slice::from_raw_parts_mut(
                self.words.as_mut_ptr() as *mut i32,
                self.words.len() * 2,
            )
        }
    }

    pub fn as_i64(&self) -> &[i64] {
        unsafe {
            std::slice::from_raw_parts(self.words.as_ptr() as *const i64, self.words.len())
        }
    }

    fn zero(&mut self) {
        self.words.fill(0);
    }
}

/// Allocator statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AllocStats {
    /// Requests served, total.
    pub allocs: u64,
    /// Requests served from the recycling pools.
    pub recycled: u64,
    /// Backing allocations performed (the expensive "CUDA API" path).
    pub backing_allocs: u64,
    /// Blocks currently live (handed out, not yet freed).
    pub live: u64,
    /// Bytes currently cached in the pools.
    pub pooled_bytes: u64,
}

/// Power-of-two size-class caching allocator.
#[derive(Debug, Default)]
pub struct CachingAllocator {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    pools: HashMap<usize, Vec<Block>>,
    stats: AllocStats,
}

/// Round a request up to its size class (power of two, minimum 64 B —
/// mirrors the CUDA allocator's minimum block granularity).
fn size_class(bytes: usize) -> usize {
    bytes.max(64).next_power_of_two()
}

impl CachingAllocator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate a zeroed block of at least `bytes` (rounded to class size).
    pub fn alloc(&self, bytes: usize) -> Block {
        let class = size_class(bytes);
        let mut inner = self.inner.lock().unwrap();
        inner.stats.allocs += 1;
        inner.stats.live += 1;
        if let Some(pool) = inner.pools.get_mut(&class) {
            if let Some(mut block) = pool.pop() {
                inner.stats.recycled += 1;
                inner.stats.pooled_bytes -= class as u64;
                block.zero();
                return block;
            }
        }
        inner.stats.backing_allocs += 1;
        Block::new_zeroed(class)
    }

    /// Return a block to its pool.
    pub fn free(&self, block: Block) {
        let class = block.len_bytes();
        debug_assert!(class.is_power_of_two() && class >= 64);
        let mut inner = self.inner.lock().unwrap();
        inner.stats.live = inner.stats.live.saturating_sub(1);
        inner.stats.pooled_bytes += class as u64;
        inner.pools.entry(class).or_default().push(block);
    }

    /// Drop all cached blocks (like `torch.cuda.empty_cache()`).
    pub fn empty_cache(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.pools.clear();
        inner.stats.pooled_bytes = 0;
    }

    pub fn stats(&self) -> AllocStats {
        self.inner.lock().unwrap().stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_classes_are_pow2() {
        assert_eq!(size_class(1), 64);
        assert_eq!(size_class(64), 64);
        assert_eq!(size_class(65), 128);
        assert_eq!(size_class(4096), 4096);
        assert_eq!(size_class(5000), 8192);
    }

    #[test]
    fn blocks_are_8_byte_aligned() {
        let a = CachingAllocator::new();
        let b = a.alloc(100);
        assert_eq!(b.as_bytes().as_ptr() as usize % 8, 0);
        assert_eq!(b.as_f32().len() * 4, b.len_bytes());
    }

    #[test]
    fn recycles_freed_blocks() {
        let a = CachingAllocator::new();
        let b1 = a.alloc(1000);
        a.free(b1);
        let _b2 = a.alloc(900); // same class (1024) -> recycled
        let s = a.stats();
        assert_eq!(s.allocs, 2);
        assert_eq!(s.backing_allocs, 1);
        assert_eq!(s.recycled, 1);
        assert_eq!(s.live, 1);
    }

    #[test]
    fn steady_state_needs_no_backing_allocs() {
        // The §4.4 claim: training-loop allocation churn hits the pool.
        let a = CachingAllocator::new();
        for _ in 0..100 {
            let b = a.alloc(4096);
            a.free(b);
        }
        let s = a.stats();
        assert_eq!(s.backing_allocs, 1);
        assert_eq!(s.recycled, 99);
    }

    #[test]
    fn recycled_blocks_are_zeroed() {
        let a = CachingAllocator::new();
        let mut b = a.alloc(128);
        b.as_bytes_mut()[7] = 0xAB;
        a.free(b);
        let b2 = a.alloc(128);
        assert!(b2.as_bytes().iter().all(|&x| x == 0));
    }

    #[test]
    fn empty_cache_releases_pools() {
        let a = CachingAllocator::new();
        a.free(a.alloc(256));
        assert!(a.stats().pooled_bytes > 0);
        a.empty_cache();
        assert_eq!(a.stats().pooled_bytes, 0);
    }

    #[test]
    fn distinct_classes_do_not_share() {
        let a = CachingAllocator::new();
        a.free(a.alloc(64));
        let _big = a.alloc(1 << 20);
        let s = a.stats();
        assert_eq!(s.recycled, 0);
        assert_eq!(s.backing_allocs, 2);
    }
}

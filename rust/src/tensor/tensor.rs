//! The `Tensor` type: PyTorch-Direct's unified tensor as a Rust library.
//!
//! API surface mirrors paper Table 1/2:
//!
//! ```ignore
//! let feats = Tensor::rand_f32(&[n, f], Device::Cpu, &mut rng);
//! let feats = feats.to(Device::Unified);          // Listing 2, line 2
//! assert!(feats.is_unified());
//! let mb = index_select(&feats, &idx, mode, &sys); // Listing 2, line 11
//! ```
//!
//! All storage physically lives in host memory (the GPU is simulated); the
//! `Device` tag governs *who is allowed to touch it* and how transfers are
//! costed, which is exactly the distinction the paper's runtime draws.

use std::sync::{Arc, OnceLock};

use crate::error::{Error, Result};
use crate::tensor::allocator::{AllocStats, Block, CachingAllocator};
use crate::tensor::device::{Device, MemAdvise};
use crate::tensor::dtype::DType;
use crate::tensor::placement::OperandKind;
use crate::util::rng::Rng;

/// Per-device global allocators (the paper's "new memory allocator ...
/// for all unified tensors" plus the native CPU/CUDA ones).
static CPU_ALLOC: OnceLock<CachingAllocator> = OnceLock::new();
static CUDA_ALLOC: OnceLock<CachingAllocator> = OnceLock::new();
static UNIFIED_ALLOC: OnceLock<CachingAllocator> = OnceLock::new();

pub fn allocator_for(device: Device) -> &'static CachingAllocator {
    match device {
        Device::Cpu => CPU_ALLOC.get_or_init(CachingAllocator::new),
        Device::Cuda => CUDA_ALLOC.get_or_init(CachingAllocator::new),
        Device::Unified => UNIFIED_ALLOC.get_or_init(CachingAllocator::new),
    }
}

/// Snapshot of the unified allocator's stats (tests / perf assertions).
pub fn unified_alloc_stats() -> AllocStats {
    UNIFIED_ALLOC.get_or_init(CachingAllocator::new).stats()
}

#[derive(Debug)]
struct Storage {
    block: Option<Block>,
    device: Device,
}

impl Storage {
    fn block(&self) -> &Block {
        self.block.as_ref().expect("storage block present until drop")
    }
}

impl Drop for Storage {
    fn drop(&mut self) {
        if let Some(block) = self.block.take() {
            allocator_for(self.device).free(block);
        }
    }
}

/// A dense, row-major tensor.
#[derive(Clone, Debug)]
pub struct Tensor {
    storage: Arc<Storage>,
    dtype: DType,
    shape: Vec<usize>,
    /// `propagatedToCUDA` placement hint (§4.2); meaningful iff unified.
    propagated: bool,
    advise: MemAdvise,
}

impl Tensor {
    // ---------------------------------------------------------- creation

    fn alloc_storage(nbytes: usize, device: Device) -> Arc<Storage> {
        Arc::new(Storage {
            block: Some(allocator_for(device).alloc(nbytes)),
            device,
        })
    }

    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize], dtype: DType, device: Device) -> Tensor {
        let numel: usize = shape.iter().product();
        Tensor {
            storage: Self::alloc_storage(numel * dtype.size_of(), device),
            dtype,
            shape: shape.to_vec(),
            propagated: true,
            advise: MemAdvise::None,
        }
    }

    /// Build from f32 data (copies).
    pub fn from_f32(data: &[f32], shape: &[usize], device: Device) -> Result<Tensor> {
        let numel: usize = shape.iter().product();
        if numel != data.len() {
            return Err(Error::Shape(format!(
                "{} values for shape {shape:?}",
                data.len()
            )));
        }
        let mut t = Tensor::zeros(shape, DType::F32, device);
        t.f32_mut().copy_from_slice(data);
        Ok(t)
    }

    /// Build from i32 data (copies).
    pub fn from_i32(data: &[i32], shape: &[usize], device: Device) -> Result<Tensor> {
        let numel: usize = shape.iter().product();
        if numel != data.len() {
            return Err(Error::Shape(format!(
                "{} values for shape {shape:?}",
                data.len()
            )));
        }
        let mut t = Tensor::zeros(shape, DType::I32, device);
        t.i32_mut().copy_from_slice(data);
        Ok(t)
    }

    /// Uniform random f32 in [lo, hi) — `torch.rand`-alike (Table 1's
    /// `torch.ones(128, device="unified")` pattern).
    pub fn rand_f32(
        shape: &[usize],
        device: Device,
        rng: &mut Rng,
        lo: f32,
        hi: f32,
    ) -> Tensor {
        let mut t = Tensor::zeros(shape, DType::F32, device);
        for v in t.f32_mut() {
            *v = rng.gen_f32_range(lo, hi);
        }
        t
    }

    /// 0-dim CPU scalar.
    pub fn scalar_f32(v: f32) -> Tensor {
        let mut t = Tensor::zeros(&[], DType::F32, Device::Cpu);
        t.f32_mut()[0] = v;
        t
    }

    // ---------------------------------------------------------- metadata

    pub fn device(&self) -> Device {
        self.storage.device
    }

    pub fn dtype(&self) -> DType {
        self.dtype
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn nbytes(&self) -> usize {
        self.numel() * self.dtype.size_of()
    }

    /// `tensor.is_unified` of Table 1.
    pub fn is_unified(&self) -> bool {
        self.device() == Device::Unified
    }

    pub fn propagated_to_cuda(&self) -> bool {
        self.propagated
    }

    pub fn advise(&self) -> MemAdvise {
        self.advise
    }

    /// Classify this tensor for the Table 3 placement rules.
    pub fn operand_kind(&self) -> OperandKind {
        match self.device() {
            Device::Cpu => {
                if self.shape.is_empty() {
                    OperandKind::CpuScalar
                } else {
                    OperandKind::CpuNonScalar
                }
            }
            Device::Cuda => OperandKind::Gpu,
            Device::Unified => {
                if self.propagated {
                    OperandKind::UnifiedPropagation
                } else {
                    OperandKind::UnifiedNonPropagation
                }
            }
        }
    }

    // ------------------------------------------------------- data access

    /// f32 view (CPU-side; valid for all devices in the simulation, which
    /// is precisely the property the paper grants only to unified tensors —
    /// callers outside tests must go through the featurestore/indexing
    /// layers that enforce and cost device access).
    pub fn f32_data(&self) -> &[f32] {
        assert_eq!(self.dtype, DType::F32, "dtype mismatch");
        &self.storage.block().as_f32()[..self.numel()]
    }

    pub fn i32_data(&self) -> &[i32] {
        assert_eq!(self.dtype, DType::I32, "dtype mismatch");
        &self.storage.block().as_i32()[..self.numel()]
    }

    fn f32_mut(&mut self) -> &mut [f32] {
        assert_eq!(self.dtype, DType::F32);
        let numel = self.numel();
        let storage = Arc::get_mut(&mut self.storage)
            .expect("mutation requires unique ownership (copy-on-write not needed here)");
        &mut storage.block.as_mut().unwrap().as_f32_mut()[..numel]
    }

    fn i32_mut(&mut self) -> &mut [i32] {
        assert_eq!(self.dtype, DType::I32);
        let numel = self.numel();
        let storage = Arc::get_mut(&mut self.storage)
            .expect("mutation requires unique ownership");
        &mut storage.block.as_mut().unwrap().as_i32_mut()[..numel]
    }

    // ---------------------------------------------------------- movement

    /// `tensor.to(device)` — copies into fresh storage on `device`.
    /// `to(Unified)` is Listing 2's two-line migration; no data layout
    /// change occurs (unified tensors live in host memory).
    pub fn to(&self, device: Device) -> Tensor {
        if device == self.device() {
            return self.clone();
        }
        let mut storage = Self::alloc_storage(self.nbytes(), device);
        {
            let s = Arc::get_mut(&mut storage).unwrap();
            let dst = s.block.as_mut().unwrap().as_bytes_mut();
            dst[..self.nbytes()].copy_from_slice(&self.storage.block().as_bytes()[..self.nbytes()]);
        }
        Tensor {
            storage,
            dtype: self.dtype,
            shape: self.shape.clone(),
            propagated: self.propagated,
            advise: MemAdvise::None, // advise is a property of the allocation
        }
    }

    /// `unified_tensor.set_propagatedToCUDA(flag)` — switches the placement
    /// hint without allocation or copy (§4.2); RuntimeError on non-unified.
    pub fn set_propagated_to_cuda(&mut self, flag: bool) -> Result<()> {
        if !self.is_unified() {
            return Err(Error::NotUnified("set_propagatedToCUDA".into()));
        }
        self.propagated = flag;
        Ok(())
    }

    /// `unified_tensor.memAdvise(advise, device)` (Table 2); RuntimeError on
    /// non-unified tensors, exactly as §4.2 specifies.
    pub fn mem_advise(&mut self, advise: MemAdvise) -> Result<()> {
        if !self.is_unified() {
            return Err(Error::NotUnified("memAdvise".into()));
        }
        self.advise = advise;
        Ok(())
    }

    // -------------------------------------------------------- arithmetic

    /// Elementwise add with the paper's mixed-device semantics: any
    /// combination involving a unified tensor is legal and placed per
    /// Table 3; same-device native combinations are legal; CPU×GPU without
    /// a unified operand is the classic PyTorch device-mismatch error.
    pub fn add(&self, other: &Tensor) -> Result<Tensor> {
        if self.dtype != DType::F32 || other.dtype != DType::F32 {
            return Err(Error::DType {
                expected: "f32".into(),
                got: format!("{}/{}", self.dtype, other.dtype),
            });
        }
        let (out_shape, scalar_rhs, scalar_lhs) = if self.shape == other.shape {
            (self.shape.clone(), false, false)
        } else if other.shape.is_empty() {
            (self.shape.clone(), true, false)
        } else if self.shape.is_empty() {
            (other.shape.clone(), false, true)
        } else {
            return Err(Error::Shape(format!(
                "add: {:?} vs {:?}",
                self.shape, other.shape
            )));
        };

        let any_unified = self.is_unified() || other.is_unified();
        let (out_device, out_prop) = if any_unified {
            let placement = crate::tensor::placement::resolve_placement(&[
                self.operand_kind(),
                other.operand_kind(),
            ]);
            match placement.output {
                crate::tensor::placement::OutputKind::Gpu => (Device::Cuda, true),
                crate::tensor::placement::OutputKind::UnifiedPropagation => {
                    (Device::Unified, true)
                }
                crate::tensor::placement::OutputKind::UnifiedNonPropagation => {
                    (Device::Unified, false)
                }
            }
        } else if self.device() == other.device() {
            (self.device(), true)
        } else if other.shape.is_empty() || self.shape.is_empty() {
            // scalar promotion across devices is allowed in PyTorch
            (
                if self.shape.is_empty() {
                    other.device()
                } else {
                    self.device()
                },
                true,
            )
        } else {
            return Err(Error::Device(format!(
                "cannot add {} tensor to {} tensor without unified type",
                self.device(),
                other.device()
            )));
        };

        let mut out = Tensor::zeros(&out_shape, DType::F32, out_device);
        out.propagated = out_prop;
        {
            let a = self.f32_data();
            let b = other.f32_data();
            let dst = out.f32_mut();
            if scalar_rhs {
                let s = b[0];
                for (d, &x) in dst.iter_mut().zip(a) {
                    *d = x + s;
                }
            } else if scalar_lhs {
                let s = a[0];
                for (d, &y) in dst.iter_mut().zip(b) {
                    *d = s + y;
                }
            } else {
                for ((d, &x), &y) in dst.iter_mut().zip(a).zip(b) {
                    *d = x + y;
                }
            }
        }
        Ok(out)
    }

    /// Sum of all elements (test/metric helper).
    pub fn sum_f32(&self) -> f32 {
        self.f32_data().iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creation_and_metadata() {
        let t = Tensor::zeros(&[2, 3], DType::F32, Device::Cpu);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.nbytes(), 24);
        assert!(!t.is_unified());
        assert_eq!(t.device(), Device::Cpu);
    }

    #[test]
    fn to_unified_is_two_line_migration() {
        // Listing 1 -> Listing 2: dataload().to("unified")
        let mut rng = Rng::new(1);
        let feats = Tensor::rand_f32(&[10, 4], Device::Cpu, &mut rng, -1.0, 1.0);
        let uni = feats.to(Device::Unified);
        assert!(uni.is_unified());
        assert_eq!(uni.f32_data(), feats.f32_data());
    }

    #[test]
    fn from_f32_shape_checked() {
        assert!(Tensor::from_f32(&[1.0, 2.0], &[3], Device::Cpu).is_err());
        let t = Tensor::from_f32(&[1.0, 2.0, 3.0], &[3], Device::Cpu).unwrap();
        assert_eq!(t.f32_data(), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn set_propagated_requires_unified() {
        let mut cpu = Tensor::zeros(&[2], DType::F32, Device::Cpu);
        assert!(matches!(
            cpu.set_propagated_to_cuda(false),
            Err(Error::NotUnified(_))
        ));
        let mut uni = cpu.to(Device::Unified);
        uni.set_propagated_to_cuda(false).unwrap();
        assert!(!uni.propagated_to_cuda());
    }

    #[test]
    fn mem_advise_requires_unified() {
        let mut cpu = Tensor::zeros(&[2], DType::F32, Device::Cpu);
        assert!(cpu.mem_advise(MemAdvise::ReadMostly).is_err());
        let mut uni = cpu.to(Device::Unified);
        uni.mem_advise(MemAdvise::ReadMostly).unwrap();
        assert_eq!(uni.advise(), MemAdvise::ReadMostly);
    }

    #[test]
    fn add_same_device() {
        let a = Tensor::from_f32(&[1.0, 2.0], &[2], Device::Cpu).unwrap();
        let b = Tensor::from_f32(&[10.0, 20.0], &[2], Device::Cpu).unwrap();
        let c = a.add(&b).unwrap();
        assert_eq!(c.f32_data(), &[11.0, 22.0]);
        assert_eq!(c.device(), Device::Cpu);
    }

    #[test]
    fn add_cpu_gpu_without_unified_fails() {
        let a = Tensor::from_f32(&[1.0, 2.0], &[2], Device::Cpu).unwrap();
        let b = Tensor::from_f32(&[1.0, 2.0], &[2], Device::Cuda).unwrap();
        assert!(matches!(a.add(&b), Err(Error::Device(_))));
    }

    #[test]
    fn add_unified_plus_cpu_follows_table3_row1() {
        // "unified_tensor + cpu_tensor" of paper Table 1: legal, and the
        // output is unified non-propagation per Table 3 row 1.
        let u = Tensor::from_f32(&[1.0, 2.0], &[2], Device::Unified).unwrap();
        let c = Tensor::from_f32(&[5.0, 6.0], &[2], Device::Cpu).unwrap();
        let out = u.add(&c).unwrap();
        assert_eq!(out.f32_data(), &[6.0, 8.0]);
        assert!(out.is_unified());
        assert!(!out.propagated_to_cuda());
    }

    #[test]
    fn add_unified_plus_gpu_gives_gpu_output() {
        // Table 3 row 2, left column.
        let u = Tensor::from_f32(&[1.0], &[1], Device::Unified).unwrap();
        let g = Tensor::from_f32(&[2.0], &[1], Device::Cuda).unwrap();
        let out = u.add(&g).unwrap();
        assert_eq!(out.device(), Device::Cuda);
    }

    #[test]
    fn add_unified_plus_scalar_gives_gpu_output() {
        // Table 3 row 3, left column ("binary ... operators accept GPU
        // scalar and CPU scalar as the two operands").
        let u = Tensor::from_f32(&[1.0, 2.0], &[2], Device::Unified).unwrap();
        let s = Tensor::scalar_f32(10.0);
        let out = u.add(&s).unwrap();
        assert_eq!(out.f32_data(), &[11.0, 12.0]);
        assert_eq!(out.device(), Device::Cuda);
    }

    #[test]
    fn allocator_recycling_via_tensor_lifecycle() {
        let before = unified_alloc_stats();
        for _ in 0..10 {
            let t = Tensor::zeros(&[1024], DType::F32, Device::Unified);
            drop(t);
        }
        let after = unified_alloc_stats();
        assert_eq!(after.allocs - before.allocs, 10);
        // at most one backing alloc for this class in this loop
        assert!(after.backing_allocs - before.backing_allocs <= 1);
    }
}

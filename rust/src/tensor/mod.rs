//! The unified-tensor runtime — the paper's §4 systems contribution,
//! implemented as a library instead of a PyTorch fork.
//!
//! * [`dtype`] / [`device`] — scalar types and the three device kinds
//!   (`cpu`, `cuda`, `unified`), with the per-tensor `propagatedToCUDA`
//!   placement hint (§4.2).
//! * [`allocator`] — caching allocator with allocation recycling, modeled
//!   on the PyTorch CUDA allocator as §4.4 describes.
//! * [`tensor`] — the `Tensor` type: creation, `.to(device)`,
//!   `is_unified`, `set_propagated_to_cuda`, `mem_advise`, arithmetic with
//!   mixed device operands, and advanced indexing.
//! * [`placement`] — the complete computation/output placement rules of
//!   paper Table 3.
//! * [`indexing`] — `index_select` with per-access-mode transfer costing:
//!   the `features[neighbor_id]` hot path of Listing 2.

pub mod allocator;
pub mod device;
pub mod dtype;
pub mod indexing;
pub mod placement;
pub mod tensor;

pub use allocator::{AllocStats, CachingAllocator};
pub use device::{Device, MemAdvise};
pub use dtype::DType;
pub use indexing::{index_select, index_select_planned, IndexSelectReport};
pub use placement::{resolve_placement, OperandKind, Placement};
pub use tensor::Tensor;

//! Computation and storage placement rules — paper §4.3, Table 3 verbatim.
//!
//! Given the operand mix of an operator that involves at least one unified
//! tensor, decide (a) which physical device executes and (b) what kind of
//! tensor the output is.  The two dispatch keys of §4.4 correspond to the
//! `UnifiedPropagation` / `UnifiedNonPropagation` operand kinds here.

use crate::tensor::device::Device;

/// Classification of one operand for placement resolution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OperandKind {
    /// CPU tensor with more than zero dimensions.
    CpuNonScalar,
    /// CPU scalar (0-dim) — PyTorch lets these mix with GPU tensors.
    CpuScalar,
    Gpu,
    /// Unified tensor with `propagatedToCUDA == true`.
    UnifiedPropagation,
    /// Unified tensor with `propagatedToCUDA == false`.
    UnifiedNonPropagation,
}

/// What the output tensor of an operation should be.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OutputKind {
    Gpu,
    UnifiedPropagation,
    UnifiedNonPropagation,
}

/// Resolved placement decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Placement {
    pub compute: Device,
    pub output: OutputKind,
}

/// Apply paper Table 3.  Panics if no operand is unified (the table is
/// defined only for operators with unified operands; native dispatch covers
/// the rest).
pub fn resolve_placement(operands: &[OperandKind]) -> Placement {
    let any_unified = operands.iter().any(|o| {
        matches!(
            o,
            OperandKind::UnifiedPropagation | OperandKind::UnifiedNonPropagation
        )
    });
    assert!(
        any_unified,
        "placement rules apply only to ops with unified operands"
    );

    let any_nonprop = operands
        .iter()
        .any(|o| *o == OperandKind::UnifiedNonPropagation);
    let any_prop = operands
        .iter()
        .any(|o| *o == OperandKind::UnifiedPropagation);
    let any_cpu_nonscalar = operands.iter().any(|o| *o == OperandKind::CpuNonScalar);
    let any_gpu = operands.iter().any(|o| *o == OperandKind::Gpu);

    // Column: "all unified tensors prefer propagation" vs "at least one
    // unified tensor prefers non-propagation".
    if !any_nonprop {
        // -- left column (all unified prefer propagation)
        if any_cpu_nonscalar {
            // Row 1: compute on GPU; output unified non-propagation.
            Placement {
                compute: Device::Cuda,
                output: OutputKind::UnifiedNonPropagation,
            }
        } else if any_gpu {
            // Row 2: compute on GPU; output GPU.
            Placement {
                compute: Device::Cuda,
                output: OutputKind::Gpu,
            }
        } else {
            // Row 3 (only CPU scalars / nothing non-unified): GPU / GPU.
            Placement {
                compute: Device::Cuda,
                output: OutputKind::Gpu,
            }
        }
    } else {
        // -- right column (at least one unified prefers non-propagation)
        if any_cpu_nonscalar {
            // Row 1: CPU if no operand prefers propagation, else GPU;
            // output unified non-propagation.
            Placement {
                compute: if any_prop { Device::Cuda } else { Device::Cpu },
                output: OutputKind::UnifiedNonPropagation,
            }
        } else if any_gpu {
            // Row 2: compute on GPU; output unified propagation.
            Placement {
                compute: Device::Cuda,
                output: OutputKind::UnifiedPropagation,
            }
        } else {
            // Row 3: CPU if no operand prefers propagation, else GPU;
            // output unified non-propagation.
            Placement {
                compute: if any_prop { Device::Cuda } else { Device::Cpu },
                output: OutputKind::UnifiedNonPropagation,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use OperandKind::*;
    use OutputKind as Out;

    // The six cells of paper Table 3, exactly.

    #[test]
    fn row1_left_cpu_nonscalar_all_prop() {
        let p = resolve_placement(&[CpuNonScalar, UnifiedPropagation]);
        assert_eq!(p.compute, Device::Cuda);
        assert_eq!(p.output, Out::UnifiedNonPropagation);
    }

    #[test]
    fn row1_right_cpu_nonscalar_some_nonprop() {
        // no propagation-preferring operand -> CPU
        let p = resolve_placement(&[CpuNonScalar, UnifiedNonPropagation]);
        assert_eq!(p.compute, Device::Cpu);
        assert_eq!(p.output, Out::UnifiedNonPropagation);
        // mixed preferences -> GPU
        let p = resolve_placement(&[CpuNonScalar, UnifiedNonPropagation, UnifiedPropagation]);
        assert_eq!(p.compute, Device::Cuda);
        assert_eq!(p.output, Out::UnifiedNonPropagation);
    }

    #[test]
    fn row2_left_gpu_all_prop() {
        let p = resolve_placement(&[Gpu, UnifiedPropagation]);
        assert_eq!(p.compute, Device::Cuda);
        assert_eq!(p.output, Out::Gpu);
    }

    #[test]
    fn row2_right_gpu_some_nonprop() {
        let p = resolve_placement(&[Gpu, UnifiedNonPropagation]);
        assert_eq!(p.compute, Device::Cuda);
        assert_eq!(p.output, Out::UnifiedPropagation);
    }

    #[test]
    fn row3_left_scalars_or_pure_unified_all_prop() {
        let p = resolve_placement(&[CpuScalar, UnifiedPropagation]);
        assert_eq!(p.compute, Device::Cuda);
        assert_eq!(p.output, Out::Gpu);
        let p = resolve_placement(&[UnifiedPropagation, UnifiedPropagation]);
        assert_eq!(p.compute, Device::Cuda);
        assert_eq!(p.output, Out::Gpu);
    }

    #[test]
    fn row3_right_scalars_or_pure_unified_some_nonprop() {
        let p = resolve_placement(&[CpuScalar, UnifiedNonPropagation]);
        assert_eq!(p.compute, Device::Cpu);
        assert_eq!(p.output, Out::UnifiedNonPropagation);
        let p = resolve_placement(&[UnifiedNonPropagation, UnifiedPropagation]);
        assert_eq!(p.compute, Device::Cuda);
        assert_eq!(p.output, Out::UnifiedNonPropagation);
    }

    #[test]
    fn row1_takes_precedence_over_row2() {
        // Both a CPU non-scalar and a GPU operand present: row 1 applies.
        let p = resolve_placement(&[CpuNonScalar, Gpu, UnifiedPropagation]);
        assert_eq!(p.output, Out::UnifiedNonPropagation);
    }

    #[test]
    #[should_panic(expected = "unified")]
    fn requires_unified_operand() {
        resolve_placement(&[CpuNonScalar, Gpu]);
    }

    /// Table 3 is a *total* function over every operand mix containing a
    /// unified tensor — exhaustively enumerate mixes up to 3 operands.
    #[test]
    fn total_over_all_mixes() {
        let kinds = [
            CpuNonScalar,
            CpuScalar,
            Gpu,
            UnifiedPropagation,
            UnifiedNonPropagation,
        ];
        let mut covered = 0;
        for &a in &kinds {
            for &b in &kinds {
                for &c in &kinds {
                    let ops = [a, b, c];
                    if ops.iter().any(|o| {
                        matches!(o, UnifiedPropagation | UnifiedNonPropagation)
                    }) {
                        let _ = resolve_placement(&ops);
                        covered += 1;
                    }
                }
            }
        }
        assert_eq!(covered, 5 * 5 * 5 - 3 * 3 * 3); // mixes with >=1 unified
    }
}

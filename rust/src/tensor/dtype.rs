//! Scalar element types.

/// Supported tensor element types.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    I32,
    I64,
    U8,
}

impl DType {
    pub fn size_of(&self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I64 => 8,
            DType::U8 => 1,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
            DType::I64 => "i64",
            DType::U8 => "u8",
        }
    }

    pub fn parse(s: &str) -> Option<DType> {
        match s {
            "f32" => Some(DType::F32),
            "i32" => Some(DType::I32),
            "i64" => Some(DType::I64),
            "u8" => Some(DType::U8),
            _ => None,
        }
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        assert_eq!(DType::F32.size_of(), 4);
        assert_eq!(DType::I64.size_of(), 8);
        assert_eq!(DType::U8.size_of(), 1);
    }

    #[test]
    fn parse_roundtrip() {
        for d in [DType::F32, DType::I32, DType::I64, DType::U8] {
            assert_eq!(DType::parse(d.name()), Some(d));
        }
        assert_eq!(DType::parse("f16"), None);
    }
}

//! `index_select` — the `features[neighbor_id]` hot path of Listing 2,
//! with per-access-mode transfer costing.
//!
//! This is the operation PyTorch-Direct modifies: for unified tensors the
//! GPU indexing kernel dereferences host memory directly (optionally with
//! the §4.5 circular-shift alignment fix); for CPU tensors the baseline
//! gathers on the host and DMA-copies.  The *data movement* is performed
//! for real (the output tensor holds the gathered rows — numerics flow into
//! training); the *device-side timing* comes from the interconnect models.

use crate::config::{AccessMode, SystemProfile};
use crate::device::warp::{count_requests, GatherTraffic, WarpModel};
use crate::error::{Error, Result};
use crate::interconnect::{DmaEngine, PcieLink, TransferCost};
use crate::sampler::compact::GatherPlan;
use crate::tensor::device::Device;
use crate::tensor::dtype::DType;
use crate::tensor::tensor::Tensor;
use crate::util::timer::Timer;

/// Outcome of one `index_select`.
#[derive(Clone, Copy, Debug, Default)]
pub struct IndexSelectReport {
    /// Simulated transfer cost on the target system.
    pub cost: TransferCost,
    /// Warp-level traffic (zero-copy modes only).
    pub traffic: Option<GatherTraffic>,
    /// Wall-clock seconds this process actually spent on the gather memcpy
    /// (diagnostic; the simulation time model does not use it directly).
    pub measured_gather_s: f64,
}

/// Gather `idx` rows of a 2-D `features` tensor into a GPU tensor, costing
/// the transfer according to `mode`.
///
/// Device requirements (the paper's semantics):
/// * `CpuGather` — features on `cpu` (the baseline has no other choice).
/// * `UnifiedNaive` / `UnifiedAligned` — features must be `unified`;
///   direct access to plain CPU tensors is exactly what native PyTorch
///   cannot do.
/// * `GpuResident` — features must be on `cuda` (and fit its memory;
///   capacity is enforced by the feature store, which owns placement).
/// * `Uvm` — stateful (resident set); use `featurestore::UvmStore`.
pub fn index_select(
    features: &Tensor,
    idx: &[u32],
    mode: AccessMode,
    sys: &SystemProfile,
) -> Result<(Tensor, IndexSelectReport)> {
    if features.dtype() != DType::F32 {
        return Err(Error::DType {
            expected: "f32".into(),
            got: features.dtype().to_string(),
        });
    }
    if features.shape().len() != 2 {
        return Err(Error::Shape(format!(
            "index_select expects [n, f], got {:?}",
            features.shape()
        )));
    }
    let n = features.shape()[0];
    let f = features.shape()[1];
    if let Some(&bad) = idx.iter().find(|&&i| i as usize >= n) {
        return Err(Error::IndexOutOfBounds {
            index: bad as usize,
            bound: n,
        });
    }

    match (mode, features.device()) {
        (AccessMode::CpuGather, Device::Cpu) => {}
        (AccessMode::CpuGather, Device::Unified) => {} // CPU may touch unified
        (AccessMode::UnifiedNaive | AccessMode::UnifiedAligned, Device::Unified) => {}
        (AccessMode::GpuResident, Device::Cuda) => {}
        (AccessMode::Uvm, _) => {
            return Err(Error::Device(
                "UVM indexing is stateful; use featurestore::UvmStore".into(),
            ))
        }
        (AccessMode::Tiered, _) => {
            return Err(Error::Device(
                "tiered indexing is stateful; use featurestore::FeatureStore::build_tiered"
                    .into(),
            ))
        }
        (AccessMode::Sharded, _) => {
            return Err(Error::Device(
                "sharded indexing is stateful; use featurestore::FeatureStore::build_sharded"
                    .into(),
            ))
        }
        (AccessMode::Nvme, _) => {
            return Err(Error::Device(
                "nvme indexing is stateful; use featurestore::FeatureStore::build_nvme".into(),
            ))
        }
        (m, d) => {
            return Err(Error::Device(format!(
                "mode {:?} cannot access features on device {d}",
                m
            )))
        }
    }

    // --- the real data movement (numerics) ---
    let timer = Timer::start();
    let mut out = Tensor::zeros(&[idx.len(), f], DType::F32, Device::Cuda);
    gather_rows_into(features.f32_data(), f, idx, unsafe_f32_mut(&mut out));
    let measured_gather_s = timer.elapsed_s();

    // --- the simulated device-side cost ---
    let row_bytes = (f * 4) as u64;
    let (cost, traffic) = match mode {
        AccessMode::CpuGather => {
            let eng = DmaEngine::new(sys);
            (eng.cpu_gather_transfer(idx.len() as u64, row_bytes), None)
        }
        AccessMode::UnifiedNaive | AccessMode::UnifiedAligned => {
            let model = WarpModel::default();
            let shifted = mode == AccessMode::UnifiedAligned && model.shift_applies(f as u64);
            let traffic = count_requests(idx, f as u64, model, shifted);
            let link = PcieLink::new(sys);
            (link.direct_gather(&traffic), Some(traffic))
        }
        AccessMode::GpuResident => (
            TransferCost {
                // device-memory gather: effectively free at this granularity
                time_s: sys.kernel_launch_s,
                bytes_on_link: 0,
                useful_bytes: idx.len() as u64 * row_bytes,
                requests: 0,
                cpu_time_s: 0.0,
                split: crate::interconnect::PathSplit {
                    local_bytes: idx.len() as u64 * row_bytes,
                    ..Default::default()
                },
            },
            None,
        ),
        AccessMode::Uvm | AccessMode::Tiered | AccessMode::Sharded | AccessMode::Nvme => {
            unreachable!()
        }
    };

    Ok((
        out,
        IndexSelectReport {
            cost,
            traffic,
            measured_gather_s,
        },
    ))
}

/// `index_select` through a [`GatherPlan`]: gather each distinct row
/// once (the transfer is costed on the deduplicated id stream), then
/// scatter the unique rows back to the requested positions via the
/// plan's inverse map.
///
/// The output tensor is `[requested_rows, f]` and bitwise identical to
/// [`index_select`] on the original duplicated stream — rows are copied,
/// never recomputed — while [`IndexSelectReport::cost`] shrinks to the
/// unique row set's traffic.  This is the tensor-level form of the
/// minibatch deduplication the follow-up papers describe
/// (arXiv:2103.03330 §4; GIDS, arXiv:2306.16384).
pub fn index_select_planned(
    features: &Tensor,
    plan: &GatherPlan,
    mode: AccessMode,
    sys: &SystemProfile,
) -> Result<(Tensor, IndexSelectReport)> {
    let (uniq, mut report) = index_select(features, plan.unique_nodes(), mode, sys)?;
    let f = features.shape()[1];
    let timer = Timer::start();
    let mut out = Tensor::zeros(&[plan.requested_rows(), f], DType::F32, Device::Cuda);
    plan.scatter_rows(uniq.f32_data(), f, unsafe_f32_mut(&mut out));
    // The scatter is a device-memory copy on real hardware; here it is
    // measured CPU work like the gather itself.
    report.measured_gather_s += timer.elapsed_s();
    Ok((out, report))
}

/// Row gather into a destination slice (the measured CPU work).
pub fn gather_rows_into(src: &[f32], f: usize, idx: &[u32], dst: &mut [f32]) {
    debug_assert_eq!(dst.len(), idx.len() * f);
    for (chunk, &r) in dst.chunks_exact_mut(f).zip(idx) {
        let lo = r as usize * f;
        chunk.copy_from_slice(&src[lo..lo + f]);
    }
}

/// Deterministic multi-threaded row gather (`--sampler-workers`).
///
/// The output is split into contiguous whole-row chunks, one per worker,
/// and each worker runs the serial [`gather_rows_into`] on its disjoint
/// slice via `std::thread::scope`.  Chunk boundaries only *partition*
/// the copy — they never reorder or restructure it — so the result is
/// bitwise identical to the single-threaded gather at every worker
/// count (pinned by `tests/parallel_gather.rs`).  The plan scatter is
/// the same operation with `idx = scatter_map`, so it parallelizes
/// through this one seam too.
///
/// A panic in any worker is caught at join and surfaced as
/// [`Error::Pipeline`] — never a hang, and never an abort of the
/// calling thread.  Workers that already wrote their chunks leave the
/// buffer partially filled; the caller must treat the error as fatal
/// for this batch (the pipeline executor does).
pub fn gather_rows_into_parallel(
    src: &[f32],
    f: usize,
    idx: &[u32],
    dst: &mut [f32],
    workers: usize,
) -> Result<()> {
    debug_assert_eq!(dst.len(), idx.len() * f);
    let w = workers.max(1).min(idx.len());
    if w <= 1 || f == 0 {
        gather_rows_into(src, f, idx, dst);
        return Ok(());
    }
    let chunk_rows = (idx.len() + w - 1) / w;
    let joined: Vec<std::thread::Result<()>> = std::thread::scope(|s| {
        let handles: Vec<_> = idx
            .chunks(chunk_rows)
            .zip(dst.chunks_mut(chunk_rows * f))
            .map(|(idx_c, dst_c)| s.spawn(move || gather_rows_into(src, f, idx_c, dst_c)))
            .collect();
        handles.into_iter().map(|h| h.join()).collect()
    });
    for r in joined {
        if let Err(p) = r {
            return Err(Error::Pipeline(format!(
                "gather worker panicked: {}",
                worker_panic_msg(p.as_ref())
            )));
        }
    }
    Ok(())
}

/// Best-effort panic payload extraction (mirrors the pipeline
/// executor's): `panic!` literals arrive as `&str`, formatted ones as
/// `String`, anything else gets a placeholder.
fn worker_panic_msg(p: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = p.downcast_ref::<&str>() {
        s
    } else if let Some(s) = p.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

/// Internal helper: mutable f32 view of a freshly created, uniquely owned
/// tensor (avoids exposing `f32_mut` publicly).
fn unsafe_f32_mut(t: &mut Tensor) -> &mut [f32] {
    // SAFETY: t was just created by the caller and has a unique Arc.
    let len = t.numel();
    let ptr = t.f32_data().as_ptr() as *mut f32;
    unsafe { std::slice::from_raw_parts_mut(ptr, len) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn feats(device: Device) -> Tensor {
        let mut rng = Rng::new(3);
        Tensor::rand_f32(&[100, 16], device, &mut rng, -1.0, 1.0)
    }

    #[test]
    fn gathers_correct_rows() {
        let f = feats(Device::Unified);
        let idx = [3u32, 97, 3, 0];
        let (out, _) =
            index_select(&f, &idx, AccessMode::UnifiedAligned, &SystemProfile::system1()).unwrap();
        assert_eq!(out.shape(), &[4, 16]);
        let src = f.f32_data();
        let got = out.f32_data();
        for (b, &r) in idx.iter().enumerate() {
            assert_eq!(
                &got[b * 16..(b + 1) * 16],
                &src[r as usize * 16..(r as usize + 1) * 16]
            );
        }
    }

    #[test]
    fn unified_modes_reject_cpu_tensor() {
        let f = feats(Device::Cpu);
        let err = index_select(&f, &[1], AccessMode::UnifiedAligned, &SystemProfile::system1());
        assert!(matches!(err, Err(Error::Device(_))));
    }

    #[test]
    fn cpu_gather_may_access_unified() {
        // "From the CPU's perspective, accessing the unified tensors is
        // identical to accessing CPU tensors." (§4.1)
        let f = feats(Device::Unified);
        assert!(index_select(&f, &[1], AccessMode::CpuGather, &SystemProfile::system1()).is_ok());
    }

    #[test]
    fn out_of_bounds_rejected() {
        let f = feats(Device::Unified);
        let err = index_select(&f, &[100], AccessMode::UnifiedAligned, &SystemProfile::system1());
        assert!(matches!(err, Err(Error::IndexOutOfBounds { .. })));
    }

    #[test]
    fn aligned_never_slower_than_naive() {
        let f = feats(Device::Unified);
        let idx: Vec<u32> = (0..64).map(|i| (i * 37) % 100).collect();
        let sys = SystemProfile::system1();
        let (_, naive) = index_select(&f, &idx, AccessMode::UnifiedNaive, &sys).unwrap();
        let (_, opt) = index_select(&f, &idx, AccessMode::UnifiedAligned, &sys).unwrap();
        assert!(opt.cost.time_s <= naive.cost.time_s);
    }

    #[test]
    fn baseline_charges_cpu_time_direct_does_not() {
        let sys = SystemProfile::system1();
        let fu = feats(Device::Unified);
        let fc = feats(Device::Cpu);
        let idx: Vec<u32> = (0..64).collect();
        let (_, py) = index_select(&fc, &idx, AccessMode::CpuGather, &sys).unwrap();
        let (_, pyd) = index_select(&fu, &idx, AccessMode::UnifiedAligned, &sys).unwrap();
        assert!(py.cost.cpu_time_s > 0.0);
        assert_eq!(pyd.cost.cpu_time_s, 0.0);
    }

    #[test]
    fn planned_select_is_bitwise_identical_and_cheaper() {
        let f = feats(Device::Unified);
        // Heavy duplication: 64 slots over 7 distinct rows.
        let idx: Vec<u32> = (0..64).map(|i| (i * 13) % 7).collect();
        let sys = SystemProfile::system1();
        let (naive, nrep) = index_select(&f, &idx, AccessMode::UnifiedAligned, &sys).unwrap();
        let plan = GatherPlan::build(&idx);
        let (planned, prep) =
            index_select_planned(&f, &plan, AccessMode::UnifiedAligned, &sys).unwrap();
        assert_eq!(planned.shape(), naive.shape());
        assert_eq!(planned.f32_data(), naive.f32_data(), "dedup changed numerics");
        assert!(prep.cost.useful_bytes < nrep.cost.useful_bytes);
        assert!(prep.cost.bytes_on_link < nrep.cost.bytes_on_link);
        assert!(prep.cost.time_s <= nrep.cost.time_s);
    }

    #[test]
    fn planned_select_costs_the_unique_stream_exactly() {
        let f = feats(Device::Unified);
        let idx = [5u32, 5, 9, 5, 9];
        let sys = SystemProfile::system1();
        let plan = GatherPlan::build(&idx);
        let (_, planned) = index_select_planned(&f, &plan, AccessMode::UnifiedNaive, &sys).unwrap();
        let (_, unique) = index_select(&f, &[5, 9], AccessMode::UnifiedNaive, &sys).unwrap();
        assert_eq!(planned.cost.time_s, unique.cost.time_s);
        assert_eq!(planned.cost.requests, unique.cost.requests);
        assert_eq!(planned.cost.bytes_on_link, unique.cost.bytes_on_link);
    }

    #[test]
    fn parallel_gather_bitwise_matches_serial_at_every_worker_count() {
        let mut rng = Rng::new(17);
        let table: Vec<f32> = (0..500 * 13).map(|_| rng.gen_f32_range(-2.0, 2.0)).collect();
        let idx: Vec<u32> = (0..331u32).map(|i| i * 7 % 500).collect();
        let mut serial = vec![0f32; idx.len() * 13];
        gather_rows_into(&table, 13, &idx, &mut serial);
        for workers in [1usize, 2, 7, 16, 100] {
            let mut par = vec![0f32; idx.len() * 13];
            gather_rows_into_parallel(&table, 13, &idx, &mut par, workers).unwrap();
            assert_eq!(par, serial, "workers={workers}");
        }
    }

    #[test]
    fn parallel_gather_handles_degenerate_shapes() {
        // Empty stream, single row, more workers than rows.
        let table: Vec<f32> = (0..40).map(|i| i as f32).collect();
        let mut empty: Vec<f32> = vec![];
        gather_rows_into_parallel(&table, 4, &[], &mut empty, 8).unwrap();
        let mut one = vec![0f32; 4];
        gather_rows_into_parallel(&table, 4, &[9], &mut one, 8).unwrap();
        assert_eq!(one, &table[36..40]);
    }

    #[test]
    fn parallel_gather_worker_panic_surfaces_as_pipeline_error() {
        // Row 99 is out of range for a 10-row table: the owning worker's
        // slice index panics, which must come back as Error::Pipeline —
        // not a hang, not a process abort.
        let table: Vec<f32> = (0..10 * 4).map(|i| i as f32).collect();
        let idx: Vec<u32> = vec![0, 1, 2, 3, 99, 5, 6, 7];
        let mut out = vec![0f32; idx.len() * 4];
        let err = gather_rows_into_parallel(&table, 4, &idx, &mut out, 4).unwrap_err();
        match err {
            Error::Pipeline(msg) => {
                assert!(msg.contains("gather worker panicked"), "{msg}")
            }
            other => panic!("expected Error::Pipeline, got {other}"),
        }
    }

    #[test]
    fn uvm_mode_directed_to_featurestore() {
        let f = feats(Device::Unified);
        assert!(index_select(&f, &[1], AccessMode::Uvm, &SystemProfile::system1()).is_err());
    }
}

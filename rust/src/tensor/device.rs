//! Device kinds and `cudaMemAdvise` hints (paper §4.2, Table 2).

/// Where a tensor's storage lives / who may access it.
///
/// `Unified` is the paper's new device: physically host-resident, directly
/// addressable by the (simulated) GPU over PCIe.  CPU tensors are
/// CPU-accessible only, GPU tensors GPU-only — unified tensors are the type
/// that "eliminates these limitations" (§4.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Device {
    Cpu,
    Cuda,
    Unified,
}

impl Device {
    pub fn parse(s: &str) -> Option<Device> {
        match s.to_ascii_lowercase().as_str() {
            "cpu" => Some(Device::Cpu),
            "cuda" | "cuda:0" | "gpu" => Some(Device::Cuda),
            "unified" => Some(Device::Unified),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Device::Cpu => "cpu",
            Device::Cuda => "cuda",
            Device::Unified => "unified",
        }
    }
}

impl std::fmt::Display for Device {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// `cudaMemAdvise` values exposed through the unified tensor API (Table 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum MemAdvise {
    #[default]
    None,
    /// Data will mostly be read; the runtime may replicate read-only copies.
    ReadMostly,
    /// Set the preferred physical location to the advise device.
    PreferredLocation,
    /// Data will be accessed by the advise device (establish mappings early).
    AccessedBy,
}

impl MemAdvise {
    pub fn parse(s: &str) -> Option<MemAdvise> {
        match s {
            "read_mostly" | "ReadMostly" => Some(MemAdvise::ReadMostly),
            "preferred_location" | "PreferredLocation" => Some(MemAdvise::PreferredLocation),
            "accessed_by" | "AccessedBy" => Some(MemAdvise::AccessedBy),
            "none" => Some(MemAdvise::None),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_devices() {
        assert_eq!(Device::parse("unified"), Some(Device::Unified));
        assert_eq!(Device::parse("CUDA"), Some(Device::Cuda));
        assert_eq!(Device::parse("tpu"), None);
    }

    #[test]
    fn parse_advise() {
        assert_eq!(MemAdvise::parse("read_mostly"), Some(MemAdvise::ReadMostly));
        assert_eq!(MemAdvise::parse("bogus"), None);
    }
}

//! # ptdirect — PyTorch-Direct reproduced as a Rust + JAX + Pallas stack
//!
//! Reproduction of *PyTorch-Direct: Enabling GPU Centric Data Access for
//! Very Large Graph Neural Network Training with Irregular Accesses*
//! (Min et al., 2021) as a three-layer system:
//!
//! * **Layer 3 (this crate)** — the data-pipeline coordinator: graph
//!   storage and generators, neighbor sampling, the unified-tensor runtime
//!   with the paper's placement rules and caching allocator, the simulated
//!   GPU/PCIe/UVM/NVLink transfer models, the tiered hot-cache feature
//!   store (GPU-resident hot set over the unified cold tier, after the
//!   Data Tiering follow-up paper — see [`featurestore::tiered`]), the
//!   multi-GPU sharded store (per-GPU hot tiers with NVLink peer access —
//!   see [`featurestore::sharded`]), the NVMe storage tier for
//!   beyond-host-memory tables (GPU-initiated block reads, GIDS-style —
//!   see [`featurestore::nvme`]), the pipelined training loop, and two
//!   training backends: the PJRT runtime that executes the AOT-compiled
//!   training step, and a built-in native trainer ([`runtime::native`])
//!   that works without artifacts.
//! * **Layer 2 (python/compile/model.py)** — GraphSAGE/GAT block models
//!   with a fused train step, lowered once to HLO text.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels (gather with
//!   the circular-shift alignment optimization, SAGE aggregation, GAT
//!   attention), interpret-mode, validated against pure-jnp oracles.
//!
//! Python never runs on the request path: `make artifacts` lowers the JAX
//! programs once; the rust binary loads and executes them via PJRT.
//!
//! See DESIGN.md §1 for the full system inventory and DESIGN.md §7 for
//! the validation/experiment index.

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod device;
pub mod error;
pub mod featurestore;
pub mod graph;
pub mod interconnect;
pub mod pipeline;
pub mod runtime;
pub mod sampler;
pub mod tensor;
pub mod util;

pub use config::{AccessMode, RunConfig, SystemProfile};
pub use error::{Error, Result};

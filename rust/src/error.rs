//! Library-wide error type.

use thiserror::Error;

/// Errors surfaced by the ptdirect library.
#[derive(Error, Debug)]
pub enum Error {
    #[error("shape mismatch: {0}")]
    Shape(String),

    #[error("device mismatch: {0}")]
    Device(String),

    /// Mirrors PyTorch-Direct's RuntimeError when unified-only APIs
    /// (set_propagatedToCUDA, memAdvise) are invoked on non-unified tensors.
    #[error("tensor is not unified: {0}")]
    NotUnified(String),

    #[error("dtype mismatch: expected {expected}, got {got}")]
    DType { expected: String, got: String },

    #[error("index out of bounds: {index} >= {bound}")]
    IndexOutOfBounds { index: usize, bound: usize },

    #[error("config error: {0}")]
    Config(String),

    #[error("graph error: {0}")]
    Graph(String),

    #[error("manifest error: {0}")]
    Manifest(String),

    #[error("artifact `{0}` not found (run `make artifacts` first)")]
    ArtifactMissing(String),

    #[error("runtime error: {0}")]
    Runtime(String),

    #[error("pipeline error: {0}")]
    Pipeline(String),

    #[error("gpu memory exceeded: need {need} bytes, capacity {capacity}")]
    GpuOom { need: u64, capacity: u64 },

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

//! Library-wide error type (hand-rolled; thiserror is not vendored offline).

use std::fmt;

/// Errors surfaced by the ptdirect library.
#[derive(Debug)]
pub enum Error {
    Shape(String),

    Device(String),

    /// Mirrors PyTorch-Direct's RuntimeError when unified-only APIs
    /// (set_propagatedToCUDA, memAdvise) are invoked on non-unified tensors.
    NotUnified(String),

    DType { expected: String, got: String },

    IndexOutOfBounds { index: usize, bound: usize },

    Config(String),

    Graph(String),

    Manifest(String),

    ArtifactMissing(String),

    Runtime(String),

    Pipeline(String),

    GpuOom { need: u64, capacity: u64 },

    Io(std::io::Error),

    Xla(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape(s) => write!(f, "shape mismatch: {s}"),
            Error::Device(s) => write!(f, "device mismatch: {s}"),
            Error::NotUnified(s) => write!(f, "tensor is not unified: {s}"),
            Error::DType { expected, got } => {
                write!(f, "dtype mismatch: expected {expected}, got {got}")
            }
            Error::IndexOutOfBounds { index, bound } => {
                write!(f, "index out of bounds: {index} >= {bound}")
            }
            Error::Config(s) => write!(f, "config error: {s}"),
            Error::Graph(s) => write!(f, "graph error: {s}"),
            Error::Manifest(s) => write!(f, "manifest error: {s}"),
            Error::ArtifactMissing(s) => {
                write!(f, "artifact `{s}` not found (run `make artifacts` first)")
            }
            Error::Runtime(s) => write!(f, "runtime error: {s}"),
            Error::Pipeline(s) => write!(f, "pipeline error: {s}"),
            Error::GpuOom { need, capacity } => {
                write!(f, "gpu memory exceeded: need {need} bytes, capacity {capacity}")
            }
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(s) => write!(f, "xla error: {s}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_variant_wording() {
        assert_eq!(
            Error::IndexOutOfBounds { index: 9, bound: 4 }.to_string(),
            "index out of bounds: 9 >= 4"
        );
        assert_eq!(
            Error::GpuOom { need: 10, capacity: 4 }.to_string(),
            "gpu memory exceeded: need 10 bytes, capacity 4"
        );
        assert!(Error::Config("x".into()).to_string().contains("config error"));
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let err: Error = io.into();
        assert!(err.to_string().contains("gone"));
        assert!(std::error::Error::source(&err).is_some());
    }
}

//! Unified link-topology registry (DESIGN.md §15).
//!
//! Every schedulable resource of the simulated machine — the CPU sampler,
//! the four transfer links (PCIe host, NVLink peer, NVMe storage, and the
//! cross-host network), and the GPU — is one [`ResourceKind`].  The kinds
//! carry a *canonical order* (the order [`ResourceKind::all`] returns and
//! every per-kind map iterates), which is load-bearing: totals and
//! utilizations are summed in canonical order, so appending a new kind
//! with a zero contribution leaves every pre-existing sum bitwise intact
//! (`x + 0.0 == x` for the non-NaN, non-negative values these maps hold).
//! That is how the network lane joined the topology without moving a
//! single pre-network report.
//!
//! [`Topology`] is the registry the cost/schedule/power layers iterate
//! instead of naming links: [`Topology::lanes`] gives the overlap engine
//! its lane shape, [`Topology::from_sys`] gives the power model each
//! link's peak bandwidth and power rail.  The concrete link models
//! (`pcie.rs`, `nvlink.rs`, `nvme.rs`, `net.rs`, `uvm.rs`) implement the
//! [`Link`] trait so generic code can ask any of them for its kind and
//! peak bandwidth.
//!
//! The per-kind maps ([`ResourceBusy`], [`LinkBytes`], [`LinkShare`]) are
//! fixed arrays indexed by the kind's ordinal — this module is the *one*
//! place that owns the kind count, so growing the topology is a one-file
//! change plus the link model itself.

use crate::config::SystemProfile;

/// Number of [`ResourceKind`] variants — the single home of the kind
/// count; every per-kind array in the crate is sized by this.
pub const NUM_RESOURCE_KINDS: usize = 6;

/// A schedulable resource of the simulated testbed: the CPU sampler
/// lanes, one of the four transfer links, or the GPU.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum ResourceKind {
    /// CPU sampler lanes (neighbor sampling + host-side gather share).
    Sampler,
    /// The PCIe host link (zero-copy host reads, DMA copies).
    HostLink,
    /// The NVLink peer link (sharded mode's GPU↔GPU reads).
    PeerLink,
    /// The NVMe storage link (GPU-initiated block reads).
    StorageLink,
    /// The cross-host network link (Ethernet/InfiniBand remote fetches).
    NetLink,
    /// The GPU compute engine (training / inference steps).
    #[default]
    Gpu,
}

impl ResourceKind {
    /// All kinds in canonical order — the order every per-kind sum,
    /// report line, and lane vector iterates.
    pub fn all() -> [ResourceKind; NUM_RESOURCE_KINDS] {
        [
            ResourceKind::Sampler,
            ResourceKind::HostLink,
            ResourceKind::PeerLink,
            ResourceKind::StorageLink,
            ResourceKind::NetLink,
            ResourceKind::Gpu,
        ]
    }

    /// Index of this kind in the canonical order (the array slot of the
    /// per-kind maps).
    pub const fn ordinal(self) -> usize {
        match self {
            ResourceKind::Sampler => 0,
            ResourceKind::HostLink => 1,
            ResourceKind::PeerLink => 2,
            ResourceKind::StorageLink => 3,
            ResourceKind::NetLink => 4,
            ResourceKind::Gpu => 5,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ResourceKind::Sampler => "sampler",
            ResourceKind::HostLink => "host-link",
            ResourceKind::PeerLink => "peer-link",
            ResourceKind::StorageLink => "storage-link",
            ResourceKind::NetLink => "net-link",
            ResourceKind::Gpu => "gpu",
        }
    }
}

/// Per-kind busy seconds (scheduling and critical-path attribution).
///
/// Array-backed so it stays `Copy` — `OverlapReport` and `ServingReport`
/// embed it by value.  `total` and `max_kind` iterate the canonical
/// order, preserving the pre-topology five-kind arithmetic bitwise when
/// the net lane is idle.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ResourceBusy {
    by_kind: [f64; NUM_RESOURCE_KINDS],
}

impl ResourceBusy {
    pub fn add(&mut self, kind: ResourceKind, s: f64) {
        self.by_kind[kind.ordinal()] += s;
    }

    pub fn get(&self, kind: ResourceKind) -> f64 {
        self.by_kind[kind.ordinal()]
    }

    /// Sum over all kinds, in canonical order.
    pub fn total(&self) -> f64 {
        let mut t = 0.0;
        for kind in ResourceKind::all() {
            t += self.by_kind[kind.ordinal()];
        }
        t
    }

    /// The busiest kind (first in canonical order wins ties).
    pub fn max_kind(&self) -> ResourceKind {
        let mut best = ResourceKind::Sampler;
        let mut best_s = 0.0;
        for kind in ResourceKind::all() {
            let s = self.get(kind);
            if s > best_s {
                best_s = s;
                best = kind;
            }
        }
        best
    }
}

/// Per-kind wire bytes (`bytes_on_link` attribution) — what the trainer
/// accumulates per epoch and hands to the power model.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LinkBytes {
    by_kind: [u64; NUM_RESOURCE_KINDS],
}

impl LinkBytes {
    pub fn add(&mut self, kind: ResourceKind, bytes: u64) {
        self.by_kind[kind.ordinal()] += bytes;
    }

    pub fn set(&mut self, kind: ResourceKind, bytes: u64) {
        self.by_kind[kind.ordinal()] = bytes;
    }

    pub fn get(&self, kind: ResourceKind) -> u64 {
        self.by_kind[kind.ordinal()]
    }
}

/// Per-kind fraction-of-epoch duty cycle — the power model's per-link
/// utilization attribution (`PowerReport::link_util`).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LinkShare {
    by_kind: [f64; NUM_RESOURCE_KINDS],
}

impl LinkShare {
    pub fn set(&mut self, kind: ResourceKind, share: f64) {
        self.by_kind[kind.ordinal()] = share;
    }

    pub fn get(&self, kind: ResourceKind) -> f64 {
        self.by_kind[kind.ordinal()]
    }
}

/// Which power rail a link draws from ([`crate::config::PowerProfile`]):
/// the host I/O complex (PCIe + NVLink + NIC) or the SSD.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PowerRail {
    Io,
    Storage,
}

/// One registered resource: its kind, lane count, and — when priced from
/// a [`SystemProfile`] — its peak bandwidth and power rail.
#[derive(Clone, Copy, Debug)]
pub struct LinkSpec {
    pub kind: ResourceKind,
    /// Service lanes the overlap engine schedules onto (1 for every link
    /// and the GPU; the sampler divides across its worker lanes).
    pub lanes: usize,
    /// Peak bandwidth in B/s (0 for the compute resources, whose cost is
    /// time, not bytes).
    pub peak_bw: f64,
    /// Power rail the link's wire bytes draw from (`None` for compute
    /// resources — their power terms are duty-cycle based).
    pub rail: Option<PowerRail>,
}

/// The registry of every resource in canonical order.
#[derive(Clone, Debug)]
pub struct Topology {
    links: Vec<LinkSpec>,
}

impl Topology {
    /// Shape-only topology for the overlap/serving engines: canonical
    /// kinds with their lane counts and no pricing.
    pub fn lanes(sampler_lanes: usize) -> Topology {
        Topology {
            links: ResourceKind::all()
                .iter()
                .map(|&kind| LinkSpec {
                    kind,
                    lanes: if kind == ResourceKind::Sampler { sampler_lanes } else { 1 },
                    peak_bw: 0.0,
                    rail: None,
                })
                .collect(),
        }
    }

    /// Priced topology for the power model: each transfer link with its
    /// profile bandwidth and power rail, in canonical order.
    pub fn from_sys(sys: &SystemProfile) -> Topology {
        Topology {
            links: vec![
                LinkSpec {
                    kind: ResourceKind::HostLink,
                    lanes: 1,
                    peak_bw: sys.pcie.peak_bw,
                    rail: Some(PowerRail::Io),
                },
                LinkSpec {
                    kind: ResourceKind::PeerLink,
                    lanes: 1,
                    peak_bw: sys.nvlink.peak_bw,
                    rail: Some(PowerRail::Io),
                },
                LinkSpec {
                    kind: ResourceKind::StorageLink,
                    lanes: 1,
                    peak_bw: sys.nvme.peak_bw,
                    rail: Some(PowerRail::Storage),
                },
                LinkSpec {
                    kind: ResourceKind::NetLink,
                    lanes: 1,
                    peak_bw: sys.net.peak_bw,
                    rail: Some(PowerRail::Io),
                },
            ],
        }
    }

    pub fn links(&self) -> &[LinkSpec] {
        &self.links
    }
}

/// Common face of the concrete link models (`PcieLink`, `NvlinkLink`,
/// `NvmeLink`, `NetLink`, `UvmSpace`): which resource lane their traffic
/// occupies and the raw bandwidth their pricing races against.
pub trait Link {
    fn kind(&self) -> ResourceKind;

    fn peak_bw(&self) -> f64;

    fn label(&self) -> &'static str {
        self.kind().label()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_cover_every_kind_in_canonical_order() {
        let all = ResourceKind::all();
        assert_eq!(all.len(), NUM_RESOURCE_KINDS);
        let labels: Vec<&str> = all.iter().map(|k| k.label()).collect();
        assert_eq!(
            labels,
            vec!["sampler", "host-link", "peer-link", "storage-link", "net-link", "gpu"]
        );
        for (i, kind) in all.iter().enumerate() {
            assert_eq!(kind.ordinal(), i, "{kind:?} out of canonical position");
        }
    }

    #[test]
    fn busy_accumulates_and_totals_in_canonical_order() {
        let mut b = ResourceBusy::default();
        b.add(ResourceKind::Sampler, 1.0);
        b.add(ResourceKind::Sampler, 0.5);
        b.add(ResourceKind::Gpu, 2.0);
        assert_eq!(b.get(ResourceKind::Sampler), 1.5);
        assert_eq!(b.get(ResourceKind::Gpu), 2.0);
        assert_eq!(b.get(ResourceKind::NetLink), 0.0);
        assert_eq!(b.total(), 3.5);
    }

    #[test]
    fn idle_net_lane_leaves_the_five_kind_total_bitwise() {
        // The degeneracy argument in one assertion: summing the canonical
        // order with a zero net term is bitwise the five-kind sum.
        let parts = [0.1, 0.2, 0.3, 0.4, 0.7];
        let old = (((parts[0] + parts[1]) + parts[2]) + parts[3]) + parts[4];
        let mut b = ResourceBusy::default();
        b.add(ResourceKind::Sampler, parts[0]);
        b.add(ResourceKind::HostLink, parts[1]);
        b.add(ResourceKind::PeerLink, parts[2]);
        b.add(ResourceKind::StorageLink, parts[3]);
        b.add(ResourceKind::Gpu, parts[4]);
        assert_eq!(b.total().to_bits(), old.to_bits());
    }

    #[test]
    fn max_kind_tie_break_is_deterministic() {
        let mut b = ResourceBusy::default();
        assert_eq!(b.max_kind(), ResourceKind::Sampler, "all-zero defaults to sampler");
        b.add(ResourceKind::HostLink, 1.0);
        b.add(ResourceKind::Gpu, 1.0);
        // Equal loads: first in canonical order wins.
        assert_eq!(b.max_kind(), ResourceKind::HostLink);
        b.add(ResourceKind::Gpu, 0.5);
        assert_eq!(b.max_kind(), ResourceKind::Gpu);
    }

    #[test]
    fn link_bytes_tracks_per_kind() {
        let mut w = LinkBytes::default();
        w.add(ResourceKind::HostLink, 100);
        w.add(ResourceKind::HostLink, 28);
        w.set(ResourceKind::NetLink, 64);
        assert_eq!(w.get(ResourceKind::HostLink), 128);
        assert_eq!(w.get(ResourceKind::NetLink), 64);
        assert_eq!(w.get(ResourceKind::StorageLink), 0);
    }

    #[test]
    fn lane_topology_covers_every_kind() {
        let t = Topology::lanes(3);
        assert_eq!(t.links().len(), NUM_RESOURCE_KINDS);
        for (spec, kind) in t.links().iter().zip(ResourceKind::all()) {
            assert_eq!(spec.kind, kind);
            let want = if kind == ResourceKind::Sampler { 3 } else { 1 };
            assert_eq!(spec.lanes, want, "{kind:?}");
        }
    }

    #[test]
    fn priced_topology_reads_the_profile_and_rails() {
        let sys = SystemProfile::system1();
        let t = Topology::from_sys(&sys);
        let find = |k: ResourceKind| {
            t.links().iter().find(|l| l.kind == k).copied().expect("registered link")
        };
        assert_eq!(find(ResourceKind::HostLink).peak_bw, sys.pcie.peak_bw);
        assert_eq!(find(ResourceKind::PeerLink).peak_bw, sys.nvlink.peak_bw);
        assert_eq!(find(ResourceKind::StorageLink).peak_bw, sys.nvme.peak_bw);
        assert_eq!(find(ResourceKind::NetLink).peak_bw, sys.net.peak_bw);
        assert_eq!(find(ResourceKind::HostLink).rail, Some(PowerRail::Io));
        assert_eq!(find(ResourceKind::NetLink).rail, Some(PowerRail::Io));
        assert_eq!(find(ResourceKind::StorageLink).rail, Some(PowerRail::Storage));
        // Canonical order holds within the priced registry too.
        let ordinals: Vec<usize> = t.links().iter().map(|l| l.kind.ordinal()).collect();
        let mut sorted = ordinals.clone();
        sorted.sort_unstable();
        assert_eq!(ordinals, sorted);
    }
}

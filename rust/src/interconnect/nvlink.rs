//! NVLink peer-to-peer link model — the GPU↔GPU path of the sharded
//! feature store (DESIGN.md §6).
//!
//! In the multi-GPU extension of PyTorch-Direct ("Large Graph
//! Convolutional Network Training with GPU-Oriented Data Communication
//! Architecture", arXiv:2103.03330), each GPU pins a shard of the feature
//! table in its own device memory and peers dereference each other's
//! memory directly over NVLink — the same zero-copy access pattern as the
//! host PCIe path, driven by the identical warp request stream, just over
//! a link with several times the bandwidth and a shorter issue round trip.
//!
//! The model is therefore deliberately symmetric with
//! [`PcieLink`](crate::interconnect::PcieLink):
//!
//! ```text
//! time = max(bandwidth-bound, request-rate-bound) + kernel launch
//! ```
//!
//! with the bandwidth bound taken over the L2-merged line traffic against
//! `peak_bw * direct_efficiency` of the [`NvlinkConfig`], and the request
//! bound as a residual per-request cost.  The symmetry is load-bearing:
//! `--mode sharded --num-gpus 1` produces *no* peer traffic and must
//! degenerate bit-exactly to the single-GPU tiered cost model, which only
//! holds because the peer path adds no asymmetric terms.

use crate::config::{NvlinkConfig, SystemProfile};
use crate::device::warp::GatherTraffic;
use crate::interconnect::topology::{Link, ResourceKind};
use crate::interconnect::{LinkPath, TransferCost, ZeroCopyLink};

/// Zero-copy peer read path over NVLink.
#[derive(Clone, Debug)]
pub struct NvlinkLink {
    cfg: NvlinkConfig,
    kernel_launch_s: f64,
}

impl NvlinkLink {
    pub fn new(sys: &SystemProfile) -> Self {
        NvlinkLink {
            cfg: sys.nvlink.clone(),
            kernel_launch_s: sys.kernel_launch_s,
        }
    }

    pub fn config(&self) -> &NvlinkConfig {
        &self.cfg
    }

    /// Zero-copy peer gather driven by a warp request stream.
    ///
    /// Same two-bound shape as
    /// [`PcieLink::direct_gather`](crate::interconnect::PcieLink::direct_gather):
    /// the requester's L2 merges a fraction of the duplicate line traffic,
    /// the merged byte count pays the bandwidth bound, the full request
    /// count pays the issue bound, and one kernel launch covers the gather.
    ///
    /// The traffic may span several peers: callers count requests *per
    /// owner* (a cacheline never straddles two GPUs' memories) and sum the
    /// components — this link then models the requester's shared NVLink
    /// ingress budget, per [`NvlinkConfig::peak_bw`]'s semantics.  The
    /// arithmetic is the shared `ZeroCopyLink` of `interconnect/mod.rs`,
    /// attributed to the peer path, so the symmetry with PCIe is
    /// structural.
    pub fn peer_gather(&self, traffic: &GatherTraffic) -> TransferCost {
        ZeroCopyLink {
            peak_bw: self.cfg.peak_bw,
            direct_efficiency: self.cfg.direct_efficiency,
            request_issue_s: self.cfg.request_issue_s,
            l2_merge_fraction: self.cfg.l2_merge_fraction,
            kernel_launch_s: self.kernel_launch_s,
        }
        .gather(traffic, LinkPath::Peer)
    }
}

impl Link for NvlinkLink {
    fn kind(&self) -> ResourceKind {
        ResourceKind::PeerLink
    }

    fn peak_bw(&self) -> f64 {
        self.cfg.peak_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::warp::{count_requests, WarpModel};
    use crate::interconnect::PcieLink;

    fn sys() -> SystemProfile {
        SystemProfile::system1()
    }

    #[test]
    fn peer_read_beats_host_read_for_the_same_traffic() {
        let s = sys();
        let idx: Vec<u32> = (0..8192u32).map(|i| i * 13 % 100_000).collect();
        let t = count_requests(&idx, 256, WarpModel::default(), false);
        let peer = NvlinkLink::new(&s).peer_gather(&t);
        let host = PcieLink::new(&s).direct_gather(&t);
        assert!(peer.time_s < host.time_s, "peer {} !< host {}", peer.time_s, host.time_s);
        assert_eq!(peer.useful_bytes, host.useful_bytes);
    }

    #[test]
    fn tiny_peer_transfers_dominated_by_launch() {
        let s = sys();
        let t = count_requests(&[1, 2, 3], 64, WarpModel::default(), false);
        let c = NvlinkLink::new(&s).peer_gather(&t);
        assert!(c.time_s > 0.9 * s.kernel_launch_s);
    }

    #[test]
    fn peer_path_attributes_bytes_to_peer_split() {
        let s = sys();
        let t = count_requests(&[5, 6, 7, 8], 128, WarpModel::default(), false);
        let c = NvlinkLink::new(&s).peer_gather(&t);
        assert_eq!(c.split.peer_bytes, c.useful_bytes);
        assert_eq!(c.split.host_bytes, 0);
        assert_eq!(c.split.local_bytes, 0);
        assert_eq!(c.cpu_time_s, 0.0);
    }

    #[test]
    fn fragmentation_costs_peer_bandwidth_too() {
        let s = sys();
        let idx: Vec<u32> = (0..4096u32).map(|i| i.wrapping_mul(2654435761) % 500_000).collect();
        let naive = count_requests(&idx, 513, WarpModel::default(), false);
        let opt = count_requests(&idx, 513, WarpModel::default(), true);
        let l = NvlinkLink::new(&s);
        assert!(l.peer_gather(&naive).time_s > l.peer_gather(&opt).time_s);
    }
}

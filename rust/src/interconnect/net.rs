//! Cross-host network link — the multi-host tier's host↔host path
//! (DESIGN.md §15).
//!
//! Symmetric in *placement* with [`crate::interconnect::NvlinkLink`] (a
//! thin wrapper over the profile constants, one pricing method, a
//! [`PathSplit`] class of its own) but deliberately coarser in
//! *mechanism*: no warp request stream crosses the NIC.  Remote feature
//! fetches under `--num-hosts > 1` are batched per-host RPCs — host 0
//! sends one request per distinct remote owner host per step, each reply
//! carries that host's rows as one contiguous payload.  The cost is
//! therefore the larger of a wire-bandwidth bound and a per-message
//! round-trip bound:
//!
//! ```text
//! time = max(wire_bytes / peak_bw, messages × latency_s)
//! ```
//!
//! with no kernel launch (the caller composes the step's launches) and no
//! CPU term (the NIC DMAs straight to pinned buffers — the same
//! CPU-bypass story the paper tells for PCIe, one level up).
//!
//! ```
//! use ptdirect::config::SystemProfile;
//! use ptdirect::interconnect::NetLink;
//!
//! let sys = SystemProfile::system1();
//! // 1 MiB of remote rows spread over 3 remote hosts.
//! let cost = NetLink::new(&sys).fetch(1 << 20, 3);
//! assert_eq!(cost.useful_bytes, 1 << 20);
//! assert_eq!(cost.cpu_time_s, 0.0); // NIC DMA: no CPU on the path
//! ```

use crate::config::{NetConfig, SystemProfile};

use super::topology::{Link, ResourceKind};
use super::{PathSplit, TransferCost};

/// Simulated cross-host network link (Ethernet/InfiniBand).
#[derive(Clone, Debug)]
pub struct NetLink {
    cfg: NetConfig,
}

impl NetLink {
    pub fn new(sys: &SystemProfile) -> Self {
        NetLink { cfg: sys.net.clone() }
    }

    pub fn config(&self) -> &NetConfig {
        &self.cfg
    }

    /// Price a batched remote fetch: `wire_bytes` of row payload pulled
    /// from `messages` distinct remote hosts (one RPC round trip each).
    ///
    /// An empty fetch (no bytes, no messages) is free — the degeneracy
    /// the `--num-hosts 1` anchor leans on.
    pub fn fetch(&self, wire_bytes: u64, messages: u64) -> TransferCost {
        let bw_bound = wire_bytes as f64 / self.cfg.peak_bw;
        let msg_bound = messages as f64 * self.cfg.latency_s;
        let link_time_s = bw_bound.max(msg_bound);
        TransferCost {
            time_s: link_time_s,
            bytes_on_link: wire_bytes,
            useful_bytes: wire_bytes,
            requests: messages,
            cpu_time_s: 0.0,
            split: PathSplit {
                net_bytes: wire_bytes,
                net_bytes_on_link: wire_bytes,
                net_time_s: link_time_s,
                ..PathSplit::default()
            },
        }
    }
}

impl Link for NetLink {
    fn kind(&self) -> ResourceKind {
        ResourceKind::NetLink
    }

    fn peak_bw(&self) -> f64 {
        self.cfg.peak_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_fetch_is_free() {
        let c = NetLink::new(&SystemProfile::system1()).fetch(0, 0);
        assert_eq!(c.time_s, 0.0);
        assert_eq!(c.bytes_on_link, 0);
        assert_eq!(c.requests, 0);
        assert_eq!(c.split.net_time_s, 0.0);
        assert_eq!(c.split.net_bytes_on_link, 0);
    }

    #[test]
    fn large_payloads_are_bandwidth_bound() {
        let sys = SystemProfile::system1();
        let bytes = 1u64 << 30;
        let c = NetLink::new(&sys).fetch(bytes, 1);
        assert_eq!(c.time_s, bytes as f64 / sys.net.peak_bw);
        assert_eq!(c.useful_bytes, bytes);
        assert_eq!(c.bytes_on_link, bytes, "no amplification on batched RPCs");
    }

    #[test]
    fn tiny_payloads_are_latency_bound() {
        let sys = SystemProfile::system1();
        let c = NetLink::new(&sys).fetch(64, 7);
        assert_eq!(c.time_s, 7.0 * sys.net.latency_s);
        assert_eq!(c.requests, 7);
    }

    #[test]
    fn split_attributes_everything_to_the_net_class() {
        let c = NetLink::new(&SystemProfile::system2()).fetch(1 << 20, 2);
        assert_eq!(c.split.net_bytes, 1 << 20);
        assert_eq!(c.split.net_bytes_on_link, c.bytes_on_link);
        assert_eq!(c.split.net_time_s, c.time_s);
        assert_eq!(c.split.host_bytes, 0);
        assert_eq!(c.split.peer_bytes, 0);
        assert_eq!(c.split.storage_bytes, 0);
        assert_eq!(c.cpu_time_s, 0.0);
        // The demand view routes the whole occupancy to the net lane.
        let d = c.demand();
        assert_eq!(d.net_s, c.time_s);
        assert_eq!(d.host_s + d.peer_s + d.storage_s + d.cpu_s, 0.0);
    }

    #[test]
    fn link_trait_reports_kind_and_bandwidth() {
        let sys = SystemProfile::system3();
        let l = NetLink::new(&sys);
        assert_eq!(l.kind(), ResourceKind::NetLink);
        assert_eq!(l.peak_bw(), sys.net.peak_bw);
        assert_eq!(l.label(), "net-link");
    }
}

//! NVMe storage-link model — the GPU↔SSD path of the three-tier store
//! (DESIGN.md §8).
//!
//! GIDS ("Accelerating Sampling and Aggregation Operations in GNN
//! Frameworks with GPU Initiated Direct Storage Accesses",
//! arXiv:2306.16384) extends the PyTorch-Direct zero-copy paradigm past
//! host memory: GPU threads submit NVMe read commands directly (BaM-style
//! queue pairs in pinned memory), so feature rows colder than the host
//! tier stream from storage with *zero CPU involvement* — the same
//! headline property as the PCIe/NVLink zero-copy paths, one tier down.
//!
//! The link differs from the byte-granular interconnects in two ways the
//! model must capture:
//!
//! * **Block granularity.**  Every command reads a whole
//!   [`NvmeConfig::block_bytes`] block (4 KiB), so sub-block feature rows
//!   amplify I/O — unless adjacent rows in the cold-store layout coalesce
//!   into shared blocks, which [`count_block_ios`] counts exactly (the
//!   storage analogue of the warp model's cacheline coalescing).
//! * **Command-rate ceiling.**  Throughput is the lesser of the bandwidth
//!   bound and a command-rate bound, where the achievable command rate is
//!   `min(iops, queue_depth / read_latency_s)` — the device's ceiling,
//!   further capped by how many commands the submission queues keep in
//!   flight (Little's law; shallow queues starve the device).
//!
//! ```text
//! time = max(bytes_on_link / peak_bw, ios / min(iops, qd / latency)) + launch
//! ```
//!
//! The two-bound shape mirrors [`ZeroCopyLink`](crate::interconnect) on
//! purpose: the storage tier composes under the host tier with the same
//! race-the-bounds arithmetic, just with block reads instead of cacheline
//! requests.
//!
//! ```
//! use ptdirect::config::SystemProfile;
//! use ptdirect::interconnect::{count_block_ios, NvmeLink};
//!
//! let sys = SystemProfile::system1();
//! // Four adjacent 516 B rows share 4 KiB blocks; scattered rows don't.
//! let adjacent = count_block_ios(&[0, 1, 2, 3], 516, 4096);
//! let scattered = count_block_ios(&[0, 100, 200, 300], 516, 4096);
//! assert!(adjacent.ios < scattered.ios);
//! assert!(adjacent.amplification() >= 1.0);
//!
//! let cost = NvmeLink::new(&sys).read(&scattered);
//! assert_eq!(cost.cpu_time_s, 0.0); // GPU-initiated: no CPU on the path
//! assert_eq!(cost.bytes_on_link, scattered.bytes_on_link);
//! ```

use crate::config::{NvmeConfig, SystemProfile};
use crate::interconnect::topology::{Link, ResourceKind};
use crate::interconnect::{PathSplit, TransferCost};

/// Block-level I/O statistics for one storage gather (the NVMe analogue
/// of [`GatherTraffic`](crate::device::warp::GatherTraffic)).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NvmeTraffic {
    /// NVMe read commands issued (= distinct blocks read; duplicate and
    /// adjacent rows coalesce, see [`count_block_ios`]).
    pub ios: u64,
    /// Bytes the SSD actually read: `ios × block_bytes`.
    pub bytes_on_link: u64,
    /// Bytes the application asked for: requested rows (duplicates
    /// included) × row size — the requester's perspective, consistent
    /// with the other links.
    pub useful_bytes: u64,
    /// Deduplicated row payload: *distinct* requested rows × row size.
    /// The amplification denominator — duplicates are served from the
    /// first block read, so counting them would understate amplification.
    pub distinct_bytes: u64,
}

impl NvmeTraffic {
    /// Block-read I/O amplification: bytes read from the device over the
    /// distinct row payload.  Always ≥ 1 — every distinct requested byte
    /// lives in exactly one counted block, and blocks are read whole
    /// (pinned by `tests/nvme_properties.rs`).
    pub fn amplification(&self) -> f64 {
        if self.distinct_bytes == 0 {
            1.0
        } else {
            self.bytes_on_link as f64 / self.distinct_bytes as f64
        }
    }
}

/// Count the distinct `block_bytes`-sized blocks a gather of cold-store
/// `slots` touches (the read-coalescing model of DESIGN.md §8).
///
/// `slots` are positions in the *packed* cold-store layout — the store
/// assigns spilled rows consecutive slots in id order, so rows adjacent
/// in the table stay adjacent on disk and share blocks.  Each slot
/// occupies bytes `[slot × row_bytes, (slot + 1) × row_bytes)`; a slot's
/// read spans every block that range overlaps, and blocks shared between
/// duplicate or neighboring slots are read once.
pub fn count_block_ios(slots: &[u32], row_bytes: u64, block_bytes: u64) -> NvmeTraffic {
    let bs = block_bytes.max(1);
    let useful_bytes = slots.len() as u64 * row_bytes;
    let mut sorted: Vec<u32> = slots.to_vec();
    sorted.sort_unstable();
    sorted.dedup();
    let distinct_bytes = sorted.len() as u64 * row_bytes;
    let mut ios = 0u64;
    // Sorted ascending slots have nondecreasing block ranges, so one pass
    // with the last counted block suffices to dedupe shared blocks.
    let mut last_counted: Option<u64> = None;
    if row_bytes > 0 {
        for &s in &sorted {
            let start_b = s as u64 * row_bytes / bs;
            let end_b = (s as u64 * row_bytes + row_bytes - 1) / bs;
            let from = match last_counted {
                Some(l) if l >= start_b => l + 1,
                _ => start_b,
            };
            if end_b >= from {
                ios += end_b - from + 1;
                last_counted = Some(end_b);
            }
        }
    }
    NvmeTraffic {
        ios,
        bytes_on_link: ios * bs,
        useful_bytes,
        distinct_bytes,
    }
}

/// [`count_block_ios`], minus the blocks another stream of the same step
/// already reads.
///
/// A composite step can touch one cold-store block from two priced
/// streams — e.g. an aggregation push-down step reads storage partials
/// for the neighbor aggregate *and* raw rows for the destination self
/// stream.  The SSD serves a block once per step, so the second stream
/// must not charge the blocks covered by `already_read` (the other
/// stream's slots) again.  `useful_bytes`/`distinct_bytes` keep their row
/// semantics — only the block I/Os and their wire bytes are deduplicated
/// against the companion stream.
pub fn count_block_ios_excluding(
    slots: &[u32],
    row_bytes: u64,
    block_bytes: u64,
    already_read: &[u32],
) -> NvmeTraffic {
    let full = count_block_ios(slots, row_bytes, block_bytes);
    if already_read.is_empty() || row_bytes == 0 || full.ios == 0 {
        return full;
    }
    let bs = block_bytes.max(1);
    let mut covered: Vec<u64> = Vec::new();
    let mut sorted: Vec<u32> = already_read.to_vec();
    sorted.sort_unstable();
    sorted.dedup();
    let mut last: Option<u64> = None;
    for &s in &sorted {
        let start_b = s as u64 * row_bytes / bs;
        let end_b = (s as u64 * row_bytes + row_bytes - 1) / bs;
        let from = match last {
            Some(l) if l >= start_b => l + 1,
            _ => start_b,
        };
        for b in from..=end_b {
            covered.push(b);
        }
        if end_b >= from {
            last = Some(end_b);
        }
    }
    // Re-walk this stream's blocks, skipping the companion's.
    let mut own: Vec<u32> = slots.to_vec();
    own.sort_unstable();
    own.dedup();
    let mut ios = 0u64;
    let mut last_counted: Option<u64> = None;
    for &s in &own {
        let start_b = s as u64 * row_bytes / bs;
        let end_b = (s as u64 * row_bytes + row_bytes - 1) / bs;
        let from = match last_counted {
            Some(l) if l >= start_b => l + 1,
            _ => start_b,
        };
        for b in from..=end_b {
            if covered.binary_search(&b).is_err() {
                ios += 1;
            }
        }
        if end_b >= from {
            last_counted = Some(end_b);
        }
    }
    NvmeTraffic {
        ios,
        bytes_on_link: ios * bs,
        useful_bytes: full.useful_bytes,
        distinct_bytes: full.distinct_bytes,
    }
}

/// GPU-initiated block-read path to the NVMe cold store.
#[derive(Clone, Debug)]
pub struct NvmeLink {
    cfg: NvmeConfig,
    kernel_launch_s: f64,
}

impl NvmeLink {
    pub fn new(sys: &SystemProfile) -> Self {
        NvmeLink {
            cfg: sys.nvme.clone(),
            kernel_launch_s: sys.kernel_launch_s,
        }
    }

    pub fn config(&self) -> &NvmeConfig {
        &self.cfg
    }

    /// Effective command rate: the device IOPS ceiling capped by what the
    /// queue-depth budget keeps in flight (`qd / latency`, Little's law).
    pub fn effective_iops(&self) -> f64 {
        let qd_rate = self.cfg.queue_depth as f64 / self.cfg.read_latency_s.max(1e-12);
        self.cfg.iops.min(qd_rate).max(1.0)
    }

    /// Cost a block-read gather: the block bytes pay the bandwidth bound,
    /// the command count pays the rate bound, and one kernel launch covers
    /// the GPU-side gather (shared with the other tiers when the storage
    /// read is part of a composite step — the store charges the launch
    /// once and sums the launch-free link occupancies).
    pub fn read(&self, traffic: &NvmeTraffic) -> TransferCost {
        let bw_bound = traffic.bytes_on_link as f64 / self.cfg.peak_bw;
        let io_bound = traffic.ios as f64 / self.effective_iops();
        let link_time_s = bw_bound.max(io_bound);
        TransferCost {
            time_s: link_time_s + self.kernel_launch_s,
            bytes_on_link: traffic.bytes_on_link,
            useful_bytes: traffic.useful_bytes,
            requests: traffic.ios,
            // GPU-initiated direct storage access — the GIDS headline.
            cpu_time_s: 0.0,
            split: PathSplit {
                storage_bytes: traffic.useful_bytes,
                storage_bytes_on_link: traffic.bytes_on_link,
                storage_time_s: link_time_s,
                ..PathSplit::default()
            },
        }
    }
}

impl Link for NvmeLink {
    fn kind(&self) -> ResourceKind {
        ResourceKind::StorageLink
    }

    fn peak_bw(&self) -> f64 {
        self.cfg.peak_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::warp::{count_requests, WarpModel};
    use crate::interconnect::PcieLink;

    fn sys() -> SystemProfile {
        SystemProfile::system1()
    }

    #[test]
    fn adjacent_rows_coalesce_into_shared_blocks() {
        // 8 × 512 B adjacent rows = exactly one 4 KiB block.
        let t = count_block_ios(&[0, 1, 2, 3, 4, 5, 6, 7], 512, 4096);
        assert_eq!(t.ios, 1);
        assert_eq!(t.bytes_on_link, 4096);
        assert!((t.amplification() - 1.0).abs() < 1e-12);
        // The same 8 rows scattered one-per-block cost 8 reads.
        let s = count_block_ios(&[0, 8, 16, 24, 32, 40, 48, 56], 512, 4096);
        assert_eq!(s.ios, 8);
        assert!((s.amplification() - 8.0).abs() < 1e-12);
    }

    #[test]
    fn duplicate_rows_read_once() {
        let t = count_block_ios(&[42, 42, 42], 512, 4096);
        assert_eq!(t.ios, 1);
        assert_eq!(t.useful_bytes, 3 * 512);
        assert_eq!(t.distinct_bytes, 512);
    }

    #[test]
    fn straddling_rows_count_both_blocks_without_double_reads() {
        // 3000 B rows: slot 1 spans blocks 0 and 1; slot 2 spans 1 and 2.
        // Block 1 is shared and must be read once: slots {1, 2} = 3 blocks.
        let t = count_block_ios(&[1, 2], 3000, 4096);
        assert_eq!(t.ios, 3);
        // A lone straddling row still reads both its blocks.
        let lone = count_block_ios(&[1], 3000, 4096);
        assert_eq!(lone.ios, 2);
    }

    #[test]
    fn amplification_at_least_one_for_random_slot_sets() {
        for seed in 0..20u64 {
            let slots: Vec<u32> = (0..200u32)
                .map(|i| (i as u64 * (seed * 2 + 3) * 2654435761 % 10_000) as u32)
                .collect();
            for row_bytes in [64u64, 516, 2052, 4096, 5000] {
                let t = count_block_ios(&slots, row_bytes, 4096);
                assert!(
                    t.amplification() >= 1.0 - 1e-12,
                    "seed {seed} row_bytes {row_bytes}: amp {}",
                    t.amplification()
                );
                assert!(t.bytes_on_link >= t.distinct_bytes);
            }
        }
    }

    #[test]
    fn excluding_covered_blocks_counts_each_block_once() {
        // 512 B rows, 8 per 4 KiB block: slots 0..8 are block 0, 8..16
        // block 1.  If a companion stream already reads slots 0..8 (block
        // 0), a stream over slots 4..12 only pays for block 1.
        let companion: Vec<u32> = (0..8).collect();
        let own: Vec<u32> = (4..12).collect();
        let t = count_block_ios_excluding(&own, 512, 4096, &companion);
        assert_eq!(t.ios, 1);
        assert_eq!(t.bytes_on_link, 4096);
        // Row semantics unchanged: useful/distinct still count own rows.
        assert_eq!(t.useful_bytes, 8 * 512);
        assert_eq!(t.distinct_bytes, 8 * 512);
        // Together the two streams read exactly the union of blocks.
        let union: Vec<u32> = (0..12).collect();
        let comp = count_block_ios(&companion, 512, 4096);
        assert_eq!(comp.ios + t.ios, count_block_ios(&union, 512, 4096).ios);
    }

    #[test]
    fn excluding_nothing_matches_the_plain_count() {
        let slots = [3u32, 77, 12, 3, 900];
        let plain = count_block_ios(&slots, 516, 4096);
        let excl = count_block_ios_excluding(&slots, 516, 4096, &[]);
        assert_eq!(plain, excl);
        // Disjoint block coverage also changes nothing.
        let far: Vec<u32> = (5000..5010).collect();
        let excl = count_block_ios_excluding(&slots, 516, 4096, &far);
        assert_eq!(plain, excl);
    }

    #[test]
    fn excluding_a_superset_leaves_zero_ios() {
        let slots = [1u32, 2, 9];
        let t = count_block_ios_excluding(&slots, 512, 4096, &[0, 1, 2, 3, 9]);
        assert_eq!(t.ios, 0);
        assert_eq!(t.bytes_on_link, 0);
        assert_eq!(t.useful_bytes, 3 * 512);
    }

    #[test]
    fn excluding_handles_straddling_rows() {
        // 3000 B rows: slot 1 spans blocks 0-1, slot 2 spans 1-2.  With
        // slot 1 already read, slot 2 only pays block 2.
        let t = count_block_ios_excluding(&[2], 3000, 4096, &[1]);
        assert_eq!(t.ios, 1);
        let both = count_block_ios(&[1, 2], 3000, 4096);
        let first = count_block_ios(&[1], 3000, 4096);
        assert_eq!(first.ios + t.ios, both.ios);
    }

    #[test]
    fn empty_and_zero_row_traffic_is_free() {
        let t = count_block_ios(&[], 512, 4096);
        assert_eq!(t.ios, 0);
        assert_eq!(t.bytes_on_link, 0);
        let z = count_block_ios(&[1, 2], 0, 4096);
        assert_eq!(z.ios, 0);
        assert!((z.amplification() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn storage_read_slower_than_host_zero_copy_for_same_rows() {
        // The tier ordering premise: the same row set costs more from
        // storage than over the host zero-copy path.
        let s = sys();
        let rows: Vec<u32> = (0..4096u32).map(|i| i * 13 % 100_000).collect();
        let dim = 129u64; // 516 B rows
        let host = PcieLink::new(&s)
            .direct_gather(&count_requests(&rows, dim, WarpModel::default(), true));
        let storage = NvmeLink::new(&s).read(&count_block_ios(&rows, dim * 4, 4096));
        assert!(
            storage.time_s > host.time_s,
            "storage {} !> host {}",
            storage.time_s,
            host.time_s
        );
    }

    #[test]
    fn shallow_queue_starves_the_device() {
        let mut s = sys();
        let rows: Vec<u32> = (0..8192u32).map(|i| i * 97 % 50_000).collect();
        let t = count_block_ios(&rows, 516, 4096);
        let deep = NvmeLink::new(&s).read(&t);
        s.nvme.queue_depth = 4; // 4 / 90 µs ≈ 44 k IOPS « device ceiling
        let shallow = NvmeLink::new(&s).read(&t);
        assert!(shallow.time_s > deep.time_s);
        // Deepening past saturation changes nothing: device-bound.
        s.nvme.queue_depth = 1 << 20;
        let very_deep = NvmeLink::new(&s).read(&t);
        assert_eq!(very_deep.time_s, deep.time_s);
    }

    #[test]
    fn storage_split_attributes_bytes_to_storage_only() {
        let c = NvmeLink::new(&sys()).read(&count_block_ios(&[5, 900, 44], 516, 4096));
        assert_eq!(c.split.storage_bytes, c.useful_bytes);
        assert_eq!(c.split.storage_bytes_on_link, c.bytes_on_link);
        assert_eq!(c.split.host_bytes, 0);
        assert_eq!(c.split.peer_bytes, 0);
        assert_eq!(c.split.local_bytes, 0);
        assert!(c.split.storage_time_s > 0.0);
        assert_eq!(c.cpu_time_s, 0.0);
    }

    #[test]
    fn tiny_storage_reads_dominated_by_launch() {
        let s = sys();
        let c = NvmeLink::new(&s).read(&count_block_ios(&[1], 64, 4096));
        assert!(c.time_s > 0.9 * s.kernel_launch_s);
    }
}

//! UVM page-migration model — the paper's §3 strawman.
//!
//! Conventional unified virtual memory moves data at page granularity
//! (>= 4 KiB) and services misses through a host interrupt path.  For
//! irregular gathers this causes (a) heavy I/O amplification — a 2 KiB
//! feature row can fault in an entire 4 KiB page, or two — and (b) a
//! per-fault service cost orders of magnitude above a PCIe read request
//! (Gera et al. 2020; Min et al. 2020).  `UvmSpace` keeps an LRU resident
//! set sized to the GPU memory so repeated epochs model page reuse and
//! thrashing.

use std::collections::HashMap;

use crate::config::SystemProfile;
use crate::interconnect::topology::{Link, ResourceKind};
use crate::interconnect::{PathSplit, TransferCost};
use crate::util::bytes::span_units;

/// Page-migration managed address space.
#[derive(Debug)]
pub struct UvmSpace {
    page_bytes: u64,
    fault_s: f64,
    bw: f64,
    capacity_pages: u64,
    /// page id -> LRU tick
    resident: HashMap<u64, u64>,
    tick: u64,
    pub faults_total: u64,
    pub evictions_total: u64,
}

impl UvmSpace {
    /// `resident_fraction` — fraction of GPU memory available for the
    /// feature pages (the rest holds model state and activations).
    pub fn new(sys: &SystemProfile, resident_fraction: f64) -> Self {
        let cap_bytes = (sys.gpu_mem_bytes as f64 * resident_fraction.clamp(0.01, 1.0)) as u64;
        UvmSpace {
            page_bytes: sys.uvm_page_bytes,
            fault_s: sys.uvm_fault_s,
            bw: sys.pcie.peak_bw * sys.pcie.dma_efficiency,
            capacity_pages: (cap_bytes / sys.uvm_page_bytes).max(1),
            resident: HashMap::new(),
            tick: 0,
            faults_total: 0,
            evictions_total: 0,
        }
    }

    pub fn page_bytes(&self) -> u64 {
        self.page_bytes
    }

    pub fn resident_pages(&self) -> usize {
        self.resident.len()
    }

    /// Access `rows` whose byte extents are produced by the caller;
    /// returns the simulated cost of the induced faults + migrations.
    pub fn access_rows(&mut self, idx: &[u32], row_bytes: u64) -> TransferCost {
        let mut faults = 0u64;
        let mut migrated_pages = 0u64;
        for &r in idx {
            let off = r as u64 * row_bytes;
            let first = off / self.page_bytes;
            let n = span_units(off, row_bytes, self.page_bytes);
            for p in first..first + n {
                self.tick += 1;
                if self.resident.contains_key(&p) {
                    self.resident.insert(p, self.tick); // LRU touch
                } else {
                    faults += 1;
                    migrated_pages += 1;
                    self.insert_with_eviction(p);
                }
            }
        }
        self.faults_total += faults;
        let moved = migrated_pages * self.page_bytes;
        let useful = idx.len() as u64 * row_bytes;
        // Fault service costs overlap only partially; model them serial
        // per fault group of 8 (driver batches nearby faults).
        let time_s = (faults as f64 / 8.0).ceil() * self.fault_s + moved as f64 / self.bw;
        TransferCost {
            time_s,
            bytes_on_link: moved,
            useful_bytes: useful,
            requests: faults,
            cpu_time_s: (faults as f64 / 8.0).ceil() * self.fault_s * 0.5, // interrupt handling
            split: PathSplit {
                host_bytes: useful,
                host_bytes_on_link: moved,
                host_time_s: time_s,
                ..PathSplit::default()
            },
        }
    }

    fn insert_with_eviction(&mut self, page: u64) {
        if self.resident.len() as u64 >= self.capacity_pages {
            // Evict the least recently used page (linear scan is fine: the
            // map is bounded by capacity_pages and eviction is the rare path
            // in the benchmarks; see DESIGN.md §7).
            if let Some((&victim, _)) = self.resident.iter().min_by_key(|(_, &t)| t) {
                self.resident.remove(&victim);
                self.evictions_total += 1;
            }
        }
        self.resident.insert(page, self.tick);
    }
}

impl Link for UvmSpace {
    /// UVM migrations ride the host link — same lane as PCIe zero-copy.
    fn kind(&self) -> ResourceKind {
        ResourceKind::HostLink
    }

    /// Effective migration bandwidth (DMA-efficiency-derated PCIe).
    fn peak_bw(&self) -> f64 {
        self.bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn space(frac: f64) -> UvmSpace {
        UvmSpace::new(&SystemProfile::system1(), frac)
    }

    #[test]
    fn first_touch_faults_then_hits() {
        let mut u = space(0.5);
        let cold = u.access_rows(&[0, 1, 2, 3], 4096);
        assert_eq!(cold.requests, 4);
        let warm = u.access_rows(&[0, 1, 2, 3], 4096);
        assert_eq!(warm.requests, 0);
        assert_eq!(warm.bytes_on_link, 0);
    }

    #[test]
    fn io_amplification_for_sub_page_rows() {
        let mut u = space(0.5);
        // 512-byte rows scattered one per page: each faults a full 4 KiB page.
        let idx: Vec<u32> = (0..64u32).map(|i| i * 8).collect();
        let c = u.access_rows(&idx, 512);
        assert!(c.bytes_on_link >= 8 * c.useful_bytes);
    }

    #[test]
    fn straddling_rows_fault_two_pages() {
        let mut u = space(0.5);
        // 2052-byte row starting at byte 2052 straddles pages 0 and 1... use
        // row index 1 with row_bytes 2052 -> offset 2052, spans 2052..4104.
        let c = u.access_rows(&[1], 2052);
        assert_eq!(c.requests, 2);
    }

    #[test]
    fn eviction_under_pressure() {
        let sys = SystemProfile::system1();
        let mut u = UvmSpace::new(&sys, 0.0); // clamps to 1% -> still huge; shrink manually
        u.capacity_pages = 16;
        let idx: Vec<u32> = (0..64u32).collect();
        u.access_rows(&idx, 4096);
        assert!(u.evictions_total > 0);
        assert!(u.resident_pages() <= 16);
    }

    #[test]
    fn uvm_slower_than_ideal_for_irregular_access() {
        let sys = SystemProfile::system1();
        let mut u = UvmSpace::new(&sys, 0.5);
        let idx: Vec<u32> = (0..1000u32).map(|i| i * 97 % 100_000).collect();
        let c = u.access_rows(&idx, 1024);
        let ideal = c.useful_bytes as f64 / sys.pcie.peak_bw;
        assert!(c.time_s > 3.0 * ideal, "uvm={} ideal={}", c.time_s, ideal);
    }
}

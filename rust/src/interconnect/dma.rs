//! CPU-centric gather + DMA pipeline — the baseline PyTorch path (Fig. 2a).
//!
//! Four steps: CPU reads the scattered rows (①), writes them into a pinned
//! staging buffer (②), launches `cudaMemcpy` (③), DMA hardware moves the
//! contiguous buffer (④).  The CPU half is *real work we actually perform*
//! (the caller does the memcpys and hands us the measured seconds); this
//! module scales that 1-core measurement to the target system's gather
//! throughput and adds the simulated DMA time.

use crate::config::SystemProfile;
use crate::interconnect::{PathSplit, TransferCost};

/// DMA engine + host gather cost model.
#[derive(Clone, Debug)]
pub struct DmaEngine {
    sys: SystemProfile,
}

impl DmaEngine {
    pub fn new(sys: &SystemProfile) -> Self {
        DmaEngine { sys: sys.clone() }
    }

    /// Host gather seconds for `rows` rows of `row_bytes` each on the target
    /// system (multithreaded, throughput saturating in row size).
    pub fn host_gather_time(&self, rows: u64, row_bytes: u64) -> f64 {
        let bytes = rows.saturating_mul(row_bytes);
        bytes as f64 / self.sys.host_gather_bw(row_bytes as f64)
    }

    /// Contiguous pinned-buffer DMA seconds for `bytes`.
    pub fn dma_time(&self, bytes: u64) -> f64 {
        self.sys.dma_setup_s
            + bytes as f64 / (self.sys.pcie.peak_bw * self.sys.pcie.dma_efficiency)
    }

    /// Full CPU-centric transfer: gather then DMA (serialized, as in the
    /// baseline PyTorch `tensor[idx].to("cuda")` idiom the paper profiles).
    pub fn cpu_gather_transfer(&self, rows: u64, row_bytes: u64) -> TransferCost {
        let useful = rows.saturating_mul(row_bytes);
        let gather_s = self.host_gather_time(rows, row_bytes);
        let dma_s = self.dma_time(useful);
        TransferCost {
            time_s: gather_s + dma_s,
            bytes_on_link: useful,
            useful_bytes: useful,
            requests: 1, // one DMA descriptor per call
            cpu_time_s: gather_s,
            split: PathSplit {
                host_bytes: useful,
                host_bytes_on_link: useful,
                host_time_s: gather_s + dma_s,
                ..PathSplit::default()
            },
        }
    }

    /// Per-row `cudaMemcpy` (the paper's §2.2 "straightforward approach"):
    /// one DMA setup per row. Kept as the ablation worst case.
    pub fn per_row_memcpy_transfer(&self, rows: u64, row_bytes: u64) -> TransferCost {
        let useful = rows.saturating_mul(row_bytes);
        let per_row = self.dma_time(row_bytes);
        TransferCost {
            time_s: per_row * rows as f64,
            bytes_on_link: useful,
            useful_bytes: useful,
            requests: rows,
            cpu_time_s: self.sys.dma_setup_s * rows as f64, // API call churn
            split: PathSplit {
                host_bytes: useful,
                host_bytes_on_link: useful,
                host_time_s: per_row * rows as f64,
                ..PathSplit::default()
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn eng() -> DmaEngine {
        DmaEngine::new(&SystemProfile::system1())
    }

    #[test]
    fn gather_plus_dma_slower_than_dma_alone() {
        let e = eng();
        let full = e.cpu_gather_transfer(10_000, 2048);
        assert!(full.time_s > e.dma_time(10_000 * 2048));
        assert!(full.cpu_time_s > 0.0);
    }

    #[test]
    fn small_rows_hurt_gather_more() {
        // Same payload, smaller rows -> strictly slower (paper Fig. 6 trend).
        let e = eng();
        let big = e.cpu_gather_transfer(1_000, 16_384);
        let small = e.cpu_gather_transfer(64_000, 256);
        assert_eq!(big.useful_bytes, small.useful_bytes);
        assert!(small.time_s > big.time_s);
    }

    #[test]
    fn per_row_memcpy_is_pathological() {
        // Paper §2.2: "making multiple calls to data copying functions incurs
        // significant overhead and can be highly inefficient."
        let e = eng();
        let batched = e.cpu_gather_transfer(4096, 1024);
        let per_row = e.per_row_memcpy_transfer(4096, 1024);
        assert!(per_row.time_s > 5.0 * batched.time_s);
    }

    #[test]
    fn system2_gather_slower_than_system1() {
        let e1 = DmaEngine::new(&SystemProfile::system1());
        let e2 = DmaEngine::new(&SystemProfile::system2());
        assert!(
            e2.cpu_gather_transfer(10_000, 1024).time_s
                > e1.cpu_gather_transfer(10_000, 1024).time_s
        );
    }
}

//! PCIe link model for GPU zero-copy ("direct") host-memory reads.
//!
//! Modern GPUs can dereference unified pointers and issue PCIe read I/O
//! directly (paper §3).  The achievable throughput is governed by how well
//! the warp-level accesses coalesce into 128-byte request windows — which is
//! exactly what [`crate::device::warp`] counts.  The link model converts a
//! [`GatherTraffic`] into time:
//!
//!   time = max(bandwidth-bound, request-rate-bound) + kernel launch
//!
//! where the bandwidth bound uses the bytes *on the link* (amplified by
//! fragmentation) against `peak_bw * direct_efficiency`, and the request
//! bound models the link's finite outstanding-read slots as a residual
//! per-request cost.

use crate::config::{PcieConfig, SystemProfile};
use crate::device::warp::GatherTraffic;
use crate::interconnect::topology::{Link, ResourceKind};
use crate::interconnect::{LinkPath, PathSplit, TransferCost, ZeroCopyLink};

/// Zero-copy read path over PCIe.
#[derive(Clone, Debug)]
pub struct PcieLink {
    cfg: PcieConfig,
    kernel_launch_s: f64,
}

impl PcieLink {
    pub fn new(sys: &SystemProfile) -> Self {
        PcieLink {
            cfg: sys.pcie.clone(),
            kernel_launch_s: sys.kernel_launch_s,
        }
    }

    pub fn config(&self) -> &PcieConfig {
        &self.cfg
    }

    /// The "ideal" transfer of paper Fig. 6: pure payload at theoretical peak.
    pub fn ideal(&self, useful_bytes: u64) -> TransferCost {
        let time_s = useful_bytes as f64 / self.cfg.peak_bw;
        TransferCost {
            time_s,
            bytes_on_link: useful_bytes,
            useful_bytes,
            requests: useful_bytes / self.cfg.cacheline_bytes.max(1),
            cpu_time_s: 0.0,
            split: PathSplit {
                host_bytes: useful_bytes,
                host_bytes_on_link: useful_bytes,
                host_time_s: time_s,
                ..PathSplit::default()
            },
        }
    }

    /// Zero-copy gather driven by a warp request stream.
    ///
    /// The GPU L2 absorbs a fraction of the *duplicate* line traffic that
    /// misaligned streams generate (adjacent warps straddling one line), so
    /// the bandwidth bound uses the merged byte count; the full request
    /// count still pays the issue cost — the shared `ZeroCopyLink`
    /// arithmetic (see `interconnect/mod.rs`), attributed to the host path.
    pub fn direct_gather(&self, traffic: &GatherTraffic) -> TransferCost {
        ZeroCopyLink {
            peak_bw: self.cfg.peak_bw,
            direct_efficiency: self.cfg.direct_efficiency,
            request_issue_s: self.cfg.request_issue_s,
            l2_merge_fraction: self.cfg.l2_merge_fraction,
            kernel_launch_s: self.kernel_launch_s,
        }
        .gather(traffic, LinkPath::Host)
    }
}

impl Link for PcieLink {
    fn kind(&self) -> ResourceKind {
        ResourceKind::HostLink
    }

    fn peak_bw(&self) -> f64 {
        self.cfg.peak_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::warp::{count_requests, WarpModel};

    fn link() -> PcieLink {
        PcieLink::new(&SystemProfile::system1())
    }

    #[test]
    fn ideal_is_payload_over_peak() {
        let c = link().ideal(15_750_000_000);
        assert!((c.time_s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn aligned_direct_is_near_ideal_for_wide_rows() {
        // Paper Fig. 6: PyD within 1.03x–1.20x of ideal except tiny transfers.
        let l = link();
        let idx: Vec<u32> = (0..32_768u32).map(|i| i * 7 % 100_000).collect();
        let feat_elems = 1024; // 4 KiB rows
        let t = count_requests(&idx, feat_elems, WarpModel::default(), true);
        let direct = l.direct_gather(&t);
        let ideal = l.ideal(t.useful_bytes);
        let slowdown = direct.time_s / ideal.time_s;
        assert!(slowdown > 1.0 && slowdown < 1.25, "slowdown={slowdown}");
    }

    #[test]
    fn tiny_transfers_dominated_by_launch_overhead() {
        // Paper §5.2: "when the total data transfer volume is very small, the
        // overall execution time is dominated by the CUDA API calls and
        // kernel launch overheads."
        let l = link();
        let idx = [1u32, 2, 3, 4];
        let t = count_requests(&idx, 64, WarpModel::default(), true);
        let direct = l.direct_gather(&t);
        assert!(direct.time_s > 0.9 * l.kernel_launch_s);
        let ideal = l.ideal(t.useful_bytes);
        assert!(direct.time_s / ideal.time_s > 2.0);
    }

    #[test]
    fn fragmentation_costs_bandwidth() {
        let l = link();
        let idx: Vec<u32> = (0..4096u32).map(|i| i.wrapping_mul(2654435761) % 500_000).collect();
        let naive = count_requests(&idx, 513, WarpModel::default(), false);
        let opt = count_requests(&idx, 513, WarpModel::default(), true);
        let t_naive = l.direct_gather(&naive).time_s;
        let t_opt = l.direct_gather(&opt).time_s;
        // paper Fig. 7: opt/naive time ratio ~1.67x at 2052 B (1.95/1.17);
        // this fixture's hashed index set coalesces better than the Fig. 7
        // uniform draw, so the gap here is smaller — the figure-level band
        // is asserted by `cargo bench --bench fig7_alignment`.
        assert!(t_naive / t_opt > 1.2, "ratio={}", t_naive / t_opt);
    }

    #[test]
    fn zero_copy_uses_no_cpu() {
        let l = link();
        let t = count_requests(&[1, 2, 3], 128, WarpModel::default(), true);
        assert_eq!(l.direct_gather(&t).cpu_time_s, 0.0);
    }
}

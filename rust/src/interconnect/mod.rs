//! Host↔GPU (and GPU↔GPU) interconnect simulator.
//!
//! Produces *simulated* durations (plain `f64` seconds, DESIGN.md §5) for
//! the transfer designs the paper and its follow-ups compare:
//!
//! * [`dma`] — the CPU-centric baseline: gather into pinned staging, then a
//!   contiguous `cudaMemcpy` DMA (paper Fig. 2a, steps ①–④).
//! * [`pcie`] — GPU-centric zero-copy reads driven by the warp request
//!   stream (paper Fig. 2b), naive or circular-shift aligned.
//! * [`uvm`] — page-migration unified memory (the §3 strawman), with fault
//!   cost and page-granularity I/O amplification.
//! * [`nvlink`] — GPU↔GPU peer zero-copy reads for the sharded multi-GPU
//!   store (DESIGN.md §6), symmetric in shape with [`pcie`].
//! * [`nvme`] — GPU-initiated NVMe block reads for the beyond-host-memory
//!   cold store (DESIGN.md §8), GIDS-style: block-granular, costed by
//!   bandwidth vs command rate under a queue-depth budget.
//!
//! ```
//! use ptdirect::config::SystemProfile;
//! use ptdirect::device::warp::{count_requests, WarpModel};
//! use ptdirect::interconnect::PcieLink;
//!
//! // Price a zero-copy gather of three feature rows (64 f32 each).
//! let sys = SystemProfile::system1();
//! let traffic = count_requests(&[7, 8, 4000], 64, WarpModel::default(), true);
//! let cost = PcieLink::new(&sys).direct_gather(&traffic);
//! assert_eq!(cost.useful_bytes, 3 * 64 * 4);
//! assert!(cost.time_s >= sys.kernel_launch_s);
//! assert_eq!(cost.cpu_time_s, 0.0); // zero-copy: no CPU on the path
//! ```

pub mod dma;
pub mod net;
pub mod nvlink;
pub mod nvme;
pub mod pcie;
pub mod topology;
pub mod uvm;

pub use dma::DmaEngine;
pub use net::NetLink;
pub use nvlink::NvlinkLink;
pub use nvme::{count_block_ios, count_block_ios_excluding, NvmeLink, NvmeTraffic};
pub use pcie::PcieLink;
pub use topology::{
    Link, LinkBytes, LinkShare, LinkSpec, PowerRail, ResourceBusy, ResourceKind, Topology,
    NUM_RESOURCE_KINDS,
};
pub use uvm::UvmSpace;

use crate::device::warp::GatherTraffic;

/// Byte/time attribution of one transfer across the five access paths of
/// the cost matrix (DESIGN.md §4/§8/§15): requester-local HBM, NVLink
/// peer, the host link (PCIe zero-copy, DMA, or UVM migration), the NVMe
/// storage link, and the cross-host network link.
///
/// Single-path modes fill exactly one class (`CpuGather`/`Uvm`/the unified
/// modes are all-host, `GpuResident` is all-local); `Tiered` splits
/// local/host; `Sharded` uses local/peer/host (plus net under
/// `--num-hosts > 1` with remote fetching); `Nvme` uses
/// local/host/storage.  `*_bytes` count *useful* payload (the requester's
/// perspective); `*_bytes_on_link` decompose
/// [`TransferCost::bytes_on_link`] (amplification included) per link, which
/// is what the power model's per-link I/O utilization consumes.
#[derive(Clone, Copy, Debug, Default)]
pub struct PathSplit {
    /// Useful bytes served from the requesting GPU's own device memory.
    pub local_bytes: u64,
    /// Useful bytes fetched from a peer GPU's hot tier over NVLink.
    pub peer_bytes: u64,
    /// Useful bytes fetched from host memory over the host link.
    pub host_bytes: u64,
    /// Useful bytes read from the NVMe cold store.
    pub storage_bytes: u64,
    /// Useful bytes fetched from a remote host over the network.
    pub net_bytes: u64,
    /// Amplified bytes that crossed the NVLink / host / storage / network
    /// link respectively (their sum is [`TransferCost::bytes_on_link`]).
    pub peer_bytes_on_link: u64,
    pub host_bytes_on_link: u64,
    /// Block-granular bytes the SSD actually read (`ios × block_bytes`).
    pub storage_bytes_on_link: u64,
    /// Wire bytes of the remote-fetch RPC payloads (no amplification —
    /// batched RPCs ship contiguous row payloads).
    pub net_bytes_on_link: u64,
    /// Simulated seconds of NVLink occupancy (summed across GPUs).  For
    /// the zero-copy links this excludes the gather-kernel launch, which
    /// is charged once per step in [`TransferCost::time_s`].
    pub peer_time_s: f64,
    /// Simulated seconds of host-link occupancy (summed across GPUs);
    /// launch-free for zero-copy, gather+DMA serial time for `CpuGather`,
    /// fault+migration time for `Uvm`.
    pub host_time_s: f64,
    /// Simulated seconds of NVMe-link occupancy (launch-free, like the
    /// other link occupancies).
    pub storage_time_s: f64,
    /// Simulated seconds of network-link occupancy (host 0's NIC).
    pub net_time_s: f64,
}

impl PathSplit {
    /// Field-wise accumulate another split into this one — the merge used
    /// when composing a step's cost from several priced streams.
    pub fn absorb(&mut self, other: &PathSplit) {
        self.local_bytes += other.local_bytes;
        self.peer_bytes += other.peer_bytes;
        self.host_bytes += other.host_bytes;
        self.storage_bytes += other.storage_bytes;
        self.net_bytes += other.net_bytes;
        self.peer_bytes_on_link += other.peer_bytes_on_link;
        self.host_bytes_on_link += other.host_bytes_on_link;
        self.storage_bytes_on_link += other.storage_bytes_on_link;
        self.net_bytes_on_link += other.net_bytes_on_link;
        self.peer_time_s += other.peer_time_s;
        self.host_time_s += other.host_time_s;
        self.storage_time_s += other.storage_time_s;
        self.net_time_s += other.net_time_s;
    }
}

/// Which link a [`ZeroCopyLink`] cost is attributed to in [`PathSplit`].
#[derive(Clone, Copy, Debug)]
pub(crate) enum LinkPath {
    Host,
    Peer,
}

/// The shared two-bound zero-copy costing used by both direct-access
/// links — PCIe host reads ([`PcieLink`]) and NVLink peer reads
/// ([`NvlinkLink`]):
///
/// ```text
/// time = max(bandwidth-bound, request-rate-bound) + kernel launch
/// ```
///
/// One implementation, parameterized by the link constants, makes the
/// PCIe/NVLink symmetry structural rather than copy-paste — the `Sharded`
/// N=1 degeneracy contract (DESIGN.md §6) leans on the two links pricing
/// identical traffic with identical arithmetic.
#[derive(Clone, Copy, Debug)]
pub(crate) struct ZeroCopyLink {
    pub peak_bw: f64,
    pub direct_efficiency: f64,
    pub request_issue_s: f64,
    pub l2_merge_fraction: f64,
    pub kernel_launch_s: f64,
}

impl ZeroCopyLink {
    /// Cost a warp-request stream: the L2 merges a fraction of the
    /// duplicate line traffic, the merged byte count pays the bandwidth
    /// bound, the full request count pays the issue bound, and one kernel
    /// launch covers the gather.
    pub(crate) fn gather(&self, traffic: &GatherTraffic, path: LinkPath) -> TransferCost {
        let bw = self.peak_bw * self.direct_efficiency;
        let excess = traffic.bytes_moved.saturating_sub(traffic.useful_bytes) as f64;
        let effective_bytes =
            traffic.useful_bytes as f64 + excess * (1.0 - self.l2_merge_fraction);
        let bw_bound = effective_bytes / bw;
        let req_bound = traffic.requests as f64 * self.request_issue_s;
        let link_time_s = bw_bound.max(req_bound);
        let split = match path {
            LinkPath::Host => PathSplit {
                host_bytes: traffic.useful_bytes,
                host_bytes_on_link: effective_bytes as u64,
                host_time_s: link_time_s,
                ..PathSplit::default()
            },
            LinkPath::Peer => PathSplit {
                peer_bytes: traffic.useful_bytes,
                peer_bytes_on_link: effective_bytes as u64,
                peer_time_s: link_time_s,
                ..PathSplit::default()
            },
        };
        TransferCost {
            time_s: link_time_s + self.kernel_launch_s,
            bytes_on_link: effective_bytes as u64,
            useful_bytes: traffic.useful_bytes,
            requests: traffic.requests,
            // Zero CPU involvement — the paper's headline property.
            cpu_time_s: 0.0,
            split,
        }
    }
}

/// Outcome of one simulated transfer.
#[derive(Clone, Copy, Debug, Default)]
pub struct TransferCost {
    /// Simulated wall-clock on the transfer path, seconds.
    pub time_s: f64,
    /// Bytes that crossed the link (including amplification).
    pub bytes_on_link: u64,
    /// Bytes the consumer asked for.
    pub useful_bytes: u64,
    /// Link-level read requests (zero-copy paths) or DMA descriptors.
    pub requests: u64,
    /// Seconds of *CPU* time this path consumed (gather/staging work);
    /// feeds the utilization + power model.
    pub cpu_time_s: f64,
    /// Per-path attribution of the useful bytes and link time.
    pub split: PathSplit,
}

impl TransferCost {
    /// Accumulate another priced stream into this cost: serial durations,
    /// link bytes, requests, CPU time, and the full per-path split all
    /// add field-wise.
    pub fn absorb(&mut self, other: &TransferCost) {
        self.time_s += other.time_s;
        self.bytes_on_link += other.bytes_on_link;
        self.useful_bytes += other.useful_bytes;
        self.requests += other.requests;
        self.cpu_time_s += other.cpu_time_s;
        self.split.absorb(&other.split);
    }

    /// Effective throughput seen by the consumer.
    pub fn effective_bw(&self) -> f64 {
        if self.time_s > 0.0 {
            self.useful_bytes as f64 / self.time_s
        } else {
            0.0
        }
    }

    /// Per-resource occupancy demand of this transfer — the busy-until
    /// interface the discrete-event overlap engine schedules
    /// (`coordinator::schedule`, DESIGN.md §9) instead of the pre-summed
    /// `time_s`.  Decomposes the transfer into the CPU share
    /// ([`TransferCost::cpu_time_s`]: staging gathers, fault servicing —
    /// work that contends with sampling for cores) and the launch-free
    /// per-link occupancies of [`PathSplit`]; `total_s` keeps the serial
    /// duration so the engine's per-step times stay exactly the serial
    /// accounting's.
    pub fn demand(&self) -> ResourceDemand {
        ResourceDemand {
            total_s: self.time_s,
            cpu_s: self.cpu_time_s,
            host_s: self.split.host_time_s,
            peer_s: self.split.peer_time_s,
            storage_s: self.split.storage_time_s,
            net_s: self.split.net_time_s,
        }
    }
}

/// Resource-occupancy view of one transfer (see [`TransferCost::demand`]):
/// what the overlap engine needs to schedule a step's feature copy onto
/// the shared links instead of adding a bare duration.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ResourceDemand {
    /// Total simulated transfer duration (== [`TransferCost::time_s`]).
    pub total_s: f64,
    /// CPU seconds on the path (gather/staging/fault work; zero for every
    /// GPU-initiated design — the paper's headline property).
    pub cpu_s: f64,
    /// Launch-free host-link occupancy seconds.
    pub host_s: f64,
    /// Launch-free NVLink peer occupancy seconds.
    pub peer_s: f64,
    /// Launch-free NVMe storage-link occupancy seconds.
    pub storage_s: f64,
    /// Launch-free network-link occupancy seconds (remote fetches).
    pub net_s: f64,
}

impl ResourceDemand {
    /// The transfer-link occupancies in canonical topology order — what
    /// the overlap/serving engines iterate instead of naming links.
    pub fn links(&self) -> [(ResourceKind, f64); 4] {
        [
            (ResourceKind::HostLink, self.host_s),
            (ResourceKind::PeerLink, self.peer_s),
            (ResourceKind::StorageLink, self.storage_s),
            (ResourceKind::NetLink, self.net_s),
        ]
    }

    /// Sum of the link occupancies, in canonical order.
    pub fn link_total(&self) -> f64 {
        let mut t = 0.0;
        for (_, s) in self.links() {
            t += s;
        }
        t
    }
}

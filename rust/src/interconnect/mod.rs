//! Host↔GPU interconnect simulator.
//!
//! Produces *simulated* durations (plain `f64` seconds, DESIGN.md §5) for
//! the three transfer designs the paper compares:
//!
//! * [`dma`] — the CPU-centric baseline: gather into pinned staging, then a
//!   contiguous `cudaMemcpy` DMA (paper Fig. 2a, steps ①–④).
//! * [`pcie`] — GPU-centric zero-copy reads driven by the warp request
//!   stream (paper Fig. 2b), naive or circular-shift aligned.
//! * [`uvm`] — page-migration unified memory (the §3 strawman), with fault
//!   cost and page-granularity I/O amplification.

pub mod dma;
pub mod pcie;
pub mod uvm;

pub use dma::DmaEngine;
pub use pcie::PcieLink;
pub use uvm::UvmSpace;

/// Outcome of one simulated transfer.
#[derive(Clone, Copy, Debug, Default)]
pub struct TransferCost {
    /// Simulated wall-clock on the transfer path, seconds.
    pub time_s: f64,
    /// Bytes that crossed the link (including amplification).
    pub bytes_on_link: u64,
    /// Bytes the consumer asked for.
    pub useful_bytes: u64,
    /// Link-level read requests (zero-copy paths) or DMA descriptors.
    pub requests: u64,
    /// Seconds of *CPU* time this path consumed (gather/staging work);
    /// feeds the utilization + power model.
    pub cpu_time_s: f64,
}

impl TransferCost {
    /// Effective throughput seen by the consumer.
    pub fn effective_bw(&self) -> f64 {
        if self.time_s > 0.0 {
            self.useful_bytes as f64 / self.time_s
        } else {
            0.0
        }
    }
}

//! Paper Fig. 7 — memory-alignment optimization sweep.
//!
//! Feature sizes 2048..2076 B in 4 B strides (64K gathers from the 4M-row
//! table, System1): the naive zero-copy kernel loses most of its benefit on
//! misaligned widths (paper: 1.17x over Py at 2052 B) while the
//! circular-shift kernel holds ~1.93x regardless of alignment.

mod bench_common;

use bench_common::{expect, scaled};
use ptdirect::config::SystemProfile;
use ptdirect::coordinator::microbench::{fig7_sizes, run_cell};
use ptdirect::coordinator::report::{ms, ratio, Table};
use ptdirect::util::rng::Rng;

fn main() {
    let sys = SystemProfile::system1();
    let mut rng = Rng::new(0xF17);
    let gathers = scaled(64u64 << 10, 8 << 10);
    let mut t = Table::new(
        &format!("Fig. 7 — alignment sweep ({}K gathers, System1)", gathers >> 10),
        &[
            "feat B", "Py ms", "PyD naive ms", "PyD opt ms", "naive vs Py", "opt vs Py",
            "opt vs naive",
        ],
    );
    let mut naive_speedups = Vec::new();
    let mut opt_speedups = Vec::new();
    for s in fig7_sizes() {
        let c = run_cell(&sys, gathers, s, &mut rng);
        let naive_sp = c.py_s / c.pyd_naive_s;
        let opt_sp = c.py_s / c.pyd_s;
        t.row(&[
            s.to_string(),
            ms(c.py_s),
            ms(c.pyd_naive_s),
            ms(c.pyd_s),
            ratio(naive_sp),
            ratio(opt_sp),
            ratio(c.pyd_naive_s / c.pyd_s),
        ]);
        if s % 128 != 0 {
            naive_speedups.push(naive_sp);
        }
        opt_speedups.push(opt_sp);
    }
    t.print();

    let naive_avg = naive_speedups.iter().sum::<f64>() / naive_speedups.len() as f64;
    let opt_avg = opt_speedups.iter().sum::<f64>() / opt_speedups.len() as f64;
    let opt_spread = opt_speedups.iter().cloned().fold(0.0, f64::max)
        - opt_speedups.iter().cloned().fold(f64::MAX, f64::min);
    println!("misaligned naive speedup avg {naive_avg:.2}x (paper ~1.17x at 2052 B)");
    println!("optimized speedup avg {opt_avg:.2}x (paper ~1.93x-1.95x)");
    println!("optimized spread across sizes {opt_spread:.3}x (paper: consistent)");

    expect((1.0..1.5).contains(&naive_avg), "naive speedup collapses on misaligned widths");
    expect((1.6..2.3).contains(&opt_avg), "optimized speedup ~1.93x");
    expect(opt_spread < 0.3, "optimized benefit consistent across alignments");
}

//! Cache sweep — eviction policy × page size × cache size over the paged
//! feature cache (DESIGN.md §12).
//!
//! Replays the shared degree-skewed trace (fixed seeds, simulated
//! pricing) against tiered stores spanning the knob grid:
//!
//!  * `static` rows are degree-ranked prefixes (the PyTorch-Direct /
//!    Data Tiering placement) — their hit rate must be monotone in the
//!    cache size at every page size;
//!  * `lfu` / `lru` / `clock` rows start cold and warm through
//!    promotion — the second replay of the identical epoch should not
//!    hit less than the first;
//!  * the `--eviction static --page-rows 1` cell must reproduce the
//!    legacy promotion-off tiered replay bit-exactly (the refactor
//!    anchor), and a full-size cache hits on every access;
//!  * every cell's internal gather pins balance (`pins == unpins`,
//!    nothing blocked) and residency stays within the page budget;
//!  * an `--eviction` × `--precision` axis (fp32/fp16/int8 storage,
//!    DESIGN.md §13) over the page-8/hot-0.25 cell: within every eviction
//!    policy, hit rates must be precision-invariant (placement is
//!    row-count based, bytes never steer residency) and warm transfer
//!    time must be non-increasing as storage narrows.
//!
//! Emits `BENCH_cache.json` — one record per grid cell, derived purely
//! from simulated quantities, so back-to-back runs are byte-identical
//! (the CI smoke loop diffs two digests).

mod bench_common;

use bench_common::{expect, replay, scaled, skewed_trace, static_tier_cfg};
use ptdirect::config::{AccessMode, EvictionPolicy, Precision, SystemProfile};
use ptdirect::coordinator::report::{ms, pct, Table};
use ptdirect::featurestore::{degree_ranking, FeatureStore, TierConfig, TierStats};
use ptdirect::graph::generator::{rmat, RmatParams};
use ptdirect::util::rng::Rng;

const NODES: usize = 20_000;
const EDGES: usize = 200_000;
/// Misaligned 516 B rows so the cold path prices like `UnifiedAligned`.
const DIM: usize = 129;
const CLASSES: u32 = 16;
const BATCH_ROWS: usize = 1024;
const SEED: u64 = 42;

const PAGE_ROWS: [usize; 3] = [1, 8, 64];
const HOT_FRACS: [f64; 3] = [0.1, 0.25, 0.5];

/// Minimal JSON string escape (labels here are plain ASCII).
fn json_str(s: &str) -> String {
    format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\""))
}

fn build(cfg: TierConfig) -> FeatureStore {
    FeatureStore::build_tiered(NODES, DIM, CLASSES, &SystemProfile::system1(), SEED, cfg)
        .expect("tiered store")
}

/// Replay one epoch; returns (simulated transfer seconds, epoch-delta
/// tier stats).
fn epoch(store: &FeatureStore, trace: &[Vec<u32>]) -> (f64, TierStats) {
    let before = store.tier_stats().expect("tier stats");
    let time = replay(store, trace);
    (time, store.tier_stats().unwrap().since(&before))
}

fn main() {
    let batches = scaled(64usize, 8);
    let graph = rmat(NODES, EDGES, RmatParams::default(), 0x71E5).expect("graph");
    let mut rng = Rng::new(0x5EE9);
    let trace = skewed_trace(&graph, &mut rng, batches, BATCH_ROWS);
    let ranking = degree_ranking(&graph);

    let mut t = Table::new(
        &format!(
            "Cache sweep — {batches} x {BATCH_ROWS}-row degree-skewed gathers, \
             {NODES} x {DIM} f32 table (System1)"
        ),
        &["policy", "pg rows", "hot frac", "cap rows", "hit cold", "hit warm", "xfer ms", "evict"],
    );
    let mut json_rows = Vec::new();
    let mut books_balance = true;
    let mut budget_held = true;
    let mut warming_held = true;
    let mut static_monotone = true;
    let mut anchor_time = f64::NAN;

    for policy in EvictionPolicy::all() {
        for &page_rows in &PAGE_ROWS {
            let mut prev_static_hit = -1.0f64;
            for &hot_frac in &HOT_FRACS {
                // Static cells replay the degree-ranked prefix; dynamic
                // policies start cold and warm through promotion.
                let is_static = policy == EvictionPolicy::Static;
                let cfg = if is_static {
                    TierConfig {
                        page_rows,
                        eviction: EvictionPolicy::Static,
                        ..static_tier_cfg(hot_frac, ranking.clone())
                    }
                } else {
                    TierConfig {
                        hot_frac,
                        reserve_bytes: 0,
                        promote: true,
                        ranking: None,
                        page_rows,
                        eviction: policy,
                    }
                };
                let store = build(cfg);
                let (_, cold) = epoch(&store, &trace);
                let (time, warm) = epoch(&store, &trace);
                let stats = store.tier_stats().unwrap();

                books_balance &= stats.pins == stats.unpins && stats.pin_blocked == 0;
                budget_held &= stats.hot_rows <= stats.capacity_rows
                    && stats.resident_pages <= stats.capacity_pages;
                if is_static {
                    static_monotone &= warm.hit_rate() >= prev_static_hit - 1e-12;
                    prev_static_hit = warm.hit_rate();
                } else {
                    warming_held &= warm.hit_rate() >= cold.hit_rate() - 1e-9;
                }
                if is_static && page_rows == 1 && hot_frac == 0.25 {
                    anchor_time = time;
                }

                t.row(&[
                    policy.label().into(),
                    page_rows.to_string(),
                    format!("{hot_frac:.2}"),
                    stats.capacity_rows.to_string(),
                    pct(cold.hit_rate()),
                    pct(warm.hit_rate()),
                    ms(time),
                    stats.evictions.to_string(),
                ]);
                json_rows.push(format!(
                    "    {{\"policy\": {}, \"page_rows\": {}, \"hot_frac\": {:.2}, \
                     \"capacity_rows\": {}, \"hit_rate_cold\": {:.6}, \
                     \"hit_rate_warm\": {:.6}, \"transfer_ms_warm\": {:.6}, \
                     \"promotions\": {}, \"evictions\": {}, \"resident_pages\": {}}}",
                    json_str(policy.label()),
                    page_rows,
                    hot_frac,
                    stats.capacity_rows,
                    cold.hit_rate(),
                    warm.hit_rate(),
                    time * 1e3,
                    stats.promotions,
                    stats.evictions,
                    stats.resident_pages,
                ));
            }
        }
    }
    t.print();

    // ---- eviction × precision axis (DESIGN.md §13) ----
    // Storage precision must never steer placement under *any* eviction
    // policy: the page-8/hot-0.25 cell replays with bitwise-identical hit
    // rates at every precision (static prefixes and warmed dynamic caches
    // alike — promotion decisions are row-count based, bytes never steer
    // residency), while the warm transfer time can only shrink as the
    // cold-path row narrows.
    let mut pt = Table::new(
        "Cache sweep eviction x precision axis — 8-row pages, hot 0.25",
        &["policy", "precision", "hit cold", "hit warm", "xfer ms"],
    );
    let mut precision_rows = Vec::new();
    let mut precision_invariant = true;
    let mut narrowing_monotone = true;
    for policy in EvictionPolicy::all() {
        let mut ref_hits: Option<(f64, f64)> = None;
        let mut prev_time = f64::INFINITY;
        for precision in Precision::all() {
            let cfg = if policy == EvictionPolicy::Static {
                TierConfig {
                    page_rows: 8,
                    eviction: EvictionPolicy::Static,
                    ..static_tier_cfg(0.25, ranking.clone())
                }
            } else {
                TierConfig {
                    hot_frac: 0.25,
                    reserve_bytes: 0,
                    promote: true,
                    ranking: None,
                    page_rows: 8,
                    eviction: policy,
                }
            };
            let store = FeatureStore::build_quantized(
                NODES,
                DIM,
                CLASSES,
                AccessMode::Tiered,
                &SystemProfile::system1(),
                SEED,
                precision,
                Some(cfg),
                None,
                None,
            )
            .expect("quantized tiered store");
            let (_, cold) = epoch(&store, &trace);
            let (time, warm) = epoch(&store, &trace);
            match ref_hits {
                None => ref_hits = Some((cold.hit_rate(), warm.hit_rate())),
                Some(r) => precision_invariant &= r == (cold.hit_rate(), warm.hit_rate()),
            }
            narrowing_monotone &= time <= prev_time;
            prev_time = time;
            pt.row(&[
                policy.label().into(),
                precision.label().into(),
                pct(cold.hit_rate()),
                pct(warm.hit_rate()),
                ms(time),
            ]);
            precision_rows.push(format!(
                "    {{\"eviction\": {}, \"precision\": {}, \"hit_rate_cold\": {:.6}, \
                 \"hit_rate_warm\": {:.6}, \"transfer_ms_warm\": {:.6}}}",
                json_str(policy.label()),
                json_str(precision.label()),
                cold.hit_rate(),
                warm.hit_rate(),
                time * 1e3,
            ));
        }
    }
    pt.print();

    let json = format!(
        "{{\n  \"bench\": \"cache_sweep\", \"nodes\": {NODES}, \"dim\": {DIM}, \
         \"batches\": {batches}, \"batch_rows\": {BATCH_ROWS},\n  \"cells\": [\n{}\n  ],\n  \
         \"precision_cells\": [\n{}\n  ]\n}}\n",
        json_rows.join(",\n"),
        precision_rows.join(",\n")
    );
    std::fs::write("BENCH_cache.json", &json).expect("write BENCH_cache.json");
    println!(
        "wrote BENCH_cache.json ({} cells + {} precision cells)",
        json_rows.len(),
        precision_rows.len()
    );
    expect(
        precision_invariant,
        "hit rates are precision-invariant under every eviction policy",
    );
    expect(
        narrowing_monotone,
        "warm transfer time non-increasing as storage narrows, per policy",
    );

    // ---- structural checks ----
    expect(books_balance, "gather pins balance in every cell (pins == unpins, none blocked)");
    expect(budget_held, "residency never exceeds the row/page budget in any cell");
    expect(
        static_monotone,
        "static hit rate monotone non-decreasing in cache size at every page size",
    );
    expect(
        warming_held,
        "replaying the identical epoch never cools a warming cache",
    );

    // Anchor: `--eviction static --page-rows 1` IS the legacy
    // promotion-off tiered replay, bit for bit.
    let legacy = build(static_tier_cfg(0.25, ranking.clone()));
    let (legacy_time, legacy_delta) = epoch(&legacy, &trace);
    let (legacy_time2, _) = epoch(&legacy, &trace);
    expect(
        anchor_time == legacy_time && legacy_time == legacy_time2,
        "static/page-rows-1 cell replays the legacy tiered epoch bit-exactly",
    );
    expect(
        legacy_delta.evictions == 0 && legacy_delta.promotions == 0,
        "static placement never promotes or evicts",
    );

    // Endpoint: a full-size preseeded cache hits on every access, for
    // every policy.
    let total: u64 = trace.iter().map(|b| b.len() as u64).sum();
    let mut full_hits = true;
    for policy in EvictionPolicy::all() {
        let store = build(TierConfig {
            hot_frac: 1.0,
            reserve_bytes: 0,
            promote: true,
            ranking: Some(ranking.clone()),
            page_rows: 1,
            eviction: policy,
        });
        let (_, delta) = epoch(&store, &trace);
        full_hits &= delta.hits == total && delta.misses == 0;
    }
    expect(full_hits, "a full-size preseeded cache hits every access under every policy");
}

//! Push-down sweep — aggregation push-down (`--aggregate-pushdown`,
//! DESIGN.md §14) over a fanout × access-mode × precision grid.
//!
//! For every cell the bench prices each batch twice against the *same*
//! pre-batch tier state: the pushed-down stream (per-destination partial
//! aggregates + counts, `FeatureStore::pushdown_cost` before the physical
//! gather) and the raw deduplicated gather the trainer would otherwise
//! pay.  Checks:
//!
//!  * strict link-byte reduction in every transfer-paying cell — all
//!    modes except `gpu` (nothing crosses a link either way) and `uvm`
//!    (the fault machinery cannot be re-run read-only; DESIGN.md §14
//!    documents the ideal-link compromise, so uvm is priced but not
//!    gated);
//!  * `gpu` ships zero bytes raw *and* pushed;
//!  * near-memory FLOPs equal off-GPU neighbor slots × feature dim in
//!    every cell;
//!  * row accounting (dst / neighbor / aggregate rows) is precision-
//!    invariant — narrowing storage moves bytes, never classification;
//!  * the measured pinned-order reduction is bitwise identical across
//!    all eight modes at each precision;
//!  * dedup × pushdown compose: dedup shrinks the self stream, leaves
//!    the aggregate stream untouched, and the composed cost still beats
//!    the raw deduplicated gather.
//!
//! Emits `BENCH_pushdown.json` — every field derives from simulated
//! quantities under fixed seeds, so back-to-back runs are byte-identical
//! (the CI smoke loop diffs two digests).

mod bench_common;

use bench_common::{expect, scaled};
use ptdirect::config::{AccessMode, Precision, ShardPolicy, SystemProfile};
use ptdirect::coordinator::report::{ratio, Table};
use ptdirect::featurestore::{
    degree_ranking, FeatureStore, NvmeStoreConfig, ShardConfig, TierConfig,
};
use ptdirect::graph::generator::{rmat, RmatParams};
use ptdirect::sampler::{AggregatePlan, GatherPlan, MiniBatch, NeighborSampler};
use ptdirect::util::bytes::human_bytes;
use ptdirect::util::rng::Rng;

const NODES: usize = 4000;
const EDGES: usize = 40_000;
const DIM: usize = 64;
const CLASSES: u32 = 16;
const SEEDS_PER_BATCH: usize = 64;
const SEED: u64 = 42;

const FANOUTS: [usize; 3] = [4, 8, 16];

/// Minimal JSON string escape (labels here are plain ASCII).
fn json_str(s: &str) -> String {
    format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\""))
}

/// Static (promotion-off) placement everywhere, so the raw-vs-pushed
/// comparison replays against identical residency in every cell.
fn build_store(mode: AccessMode, precision: Precision, ranking: &[u32]) -> FeatureStore {
    let sys = SystemProfile::system1();
    let tier = |hot: f64| TierConfig {
        hot_frac: hot,
        reserve_bytes: 0,
        promote: false,
        ranking: Some(ranking.to_vec()),
        ..TierConfig::default()
    };
    let (tc, sc, nc) = match mode {
        AccessMode::Tiered => (Some(tier(0.25)), None, None),
        AccessMode::Sharded => (
            None,
            Some(ShardConfig {
                num_gpus: 4,
                policy: ShardPolicy::Hash,
                tier: tier(0.5),
                ..ShardConfig::default()
            }),
            None,
        ),
        AccessMode::Nvme => (None, None, Some(NvmeStoreConfig { host_frac: 0.9, tier: tier(0.1) })),
        _ => (None, None, None),
    };
    FeatureStore::build_quantized(NODES, DIM, CLASSES, mode, &sys, SEED, precision, tc, sc, nc)
        .expect("store")
}

fn main() {
    let batches = scaled(6usize, 2);
    let graph = rmat(NODES, EDGES, RmatParams::default(), 0x71E5).expect("graph");
    let ranking = degree_ranking(&graph);

    // One trace per fanout, shared across every (mode, precision) cell so
    // cross-cell comparisons see identical batches.
    let traces: Vec<Vec<MiniBatch>> = FANOUTS
        .iter()
        .map(|&fo| {
            let sampler = NeighborSampler::new(&graph, &[fo], CLASSES);
            let mut rng = Rng::new(0xA11CE ^ fo as u64);
            (0..batches)
                .map(|_| {
                    let seeds: Vec<u32> = (0..SEEDS_PER_BATCH)
                        .map(|_| rng.gen_range(NODES as u64) as u32)
                        .collect();
                    sampler.sample(&seeds, &mut rng)
                })
                .collect()
        })
        .collect();

    let mut t = Table::new(
        &format!(
            "Push-down sweep — {batches} x {SEEDS_PER_BATCH}-seed batches, \
             {NODES} x {DIM} table (System1, dedup on)"
        ),
        &["mode", "prec", "fanout", "raw link", "pushed link", "reduction", "nm MFLOP"],
    );
    let mut json_rows = Vec::new();
    let mut strict_reduction = true;
    let mut gpu_ships_nothing = true;
    let mut flops_match = true;
    let mut rows_precision_invariant = true;
    let mut reduction_bitwise = true;
    // Row accounting from the fp32 pass, keyed by (mode, fanout) position.
    let mut fp32_rows: Vec<Vec<(u64, u64, u64)>> = Vec::new();
    // Reference reduction bits per precision (set by the first mode seen).
    let mut agg_ref: Vec<Option<Vec<u32>>> = vec![None; Precision::all().len()];

    for (mi, &mode) in AccessMode::all().iter().enumerate() {
        for (pi, &precision) in Precision::all().iter().enumerate() {
            for (fi, &fo) in FANOUTS.iter().enumerate() {
                let store = build_store(mode, precision, &ranking);
                let mut raw_bytes = 0u64;
                let mut pushed_bytes = 0u64;
                let mut nm_flops = 0u64;
                let mut dst_rows = 0u64;
                let mut nbr_rows = 0u64;
                let mut agg_rows = 0u64;
                for (bi, mb) in traces[fi].iter().enumerate() {
                    let plan = AggregatePlan::build(mb).expect("plan");
                    // Price the pushed stream BEFORE the physical gather:
                    // classification must see the pre-batch tier state the
                    // raw gather's own classifier sees.
                    let pd = store.pushdown_cost(&plan, true).expect("pushdown");
                    let gplan = GatherPlan::build(&mb.src_nodes);
                    let mut x0 = vec![0f32; gplan.requested_rows() * DIM];
                    let raw = store.gather_planned(&gplan, &mut x0).expect("gather");
                    raw_bytes += raw.bytes_on_link;
                    pushed_bytes += pd.cost.bytes_on_link;
                    nm_flops += pd.near_mem_flops;
                    dst_rows += pd.dst_rows;
                    nbr_rows += pd.neighbor_rows;
                    agg_rows += pd.agg_rows;
                    flops_match &= pd.near_mem_flops == pd.off_gpu_neighbor_rows * DIM as u64;
                    if bi == 0 && fi == 0 {
                        // The measured pinned-order reduction must be
                        // bitwise identical in every mode (same precision).
                        let mut agg = vec![0f32; plan.n_dst() * DIM];
                        let mut counts = vec![0u32; plan.n_dst()];
                        plan.aggregate_gathered(&x0, DIM, &mut agg, &mut counts).expect("reduce");
                        let bits: Vec<u32> = agg.iter().map(|v| v.to_bits()).collect();
                        match &agg_ref[pi] {
                            None => agg_ref[pi] = Some(bits),
                            Some(r) => reduction_bitwise &= &bits == r,
                        }
                    }
                }
                match mode {
                    AccessMode::GpuResident => {
                        gpu_ships_nothing &= raw_bytes == 0 && pushed_bytes == 0;
                    }
                    AccessMode::Uvm => {} // priced, not gated (DESIGN.md §14)
                    _ => strict_reduction &= pushed_bytes < raw_bytes,
                }
                if precision == Precision::Fp32 {
                    if fp32_rows.len() == mi {
                        fp32_rows.push(Vec::new());
                    }
                    fp32_rows[mi].push((dst_rows, nbr_rows, agg_rows));
                } else {
                    rows_precision_invariant &=
                        fp32_rows[mi][fi] == (dst_rows, nbr_rows, agg_rows);
                }
                let reduction =
                    if pushed_bytes == 0 { 1.0 } else { raw_bytes as f64 / pushed_bytes as f64 };
                t.row(&[
                    mode.label().into(),
                    precision.label().into(),
                    fo.to_string(),
                    human_bytes(raw_bytes),
                    human_bytes(pushed_bytes),
                    ratio(reduction),
                    format!("{:.1}", nm_flops as f64 / 1e6),
                ]);
                json_rows.push(format!(
                    "    {{\"mode\": {}, \"precision\": {}, \"fanout\": {}, \
                     \"raw_bytes_on_link\": {}, \"pushed_bytes_on_link\": {}, \
                     \"reduction\": {:.6}, \"dst_rows\": {}, \"neighbor_rows\": {}, \
                     \"agg_rows\": {}, \"near_mem_flops\": {}}}",
                    json_str(mode.label()),
                    json_str(precision.label()),
                    fo,
                    raw_bytes,
                    pushed_bytes,
                    reduction,
                    dst_rows,
                    nbr_rows,
                    agg_rows,
                    nm_flops,
                ));
            }
        }
    }
    t.print();

    let json = format!(
        "{{\n  \"bench\": \"pushdown_sweep\", \"nodes\": {NODES}, \"dim\": {DIM}, \
         \"batches\": {batches}, \"seeds_per_batch\": {SEEDS_PER_BATCH},\n  \"cells\": [\n{}\n  ]\n}}\n",
        json_rows.join(",\n")
    );
    std::fs::write("BENCH_pushdown.json", &json).expect("write BENCH_pushdown.json");
    println!("wrote BENCH_pushdown.json ({} cells)", json_rows.len());

    // ---- structural checks ----
    expect(
        strict_reduction,
        "pushed stream strictly cuts link bytes in every transfer-paying cell",
    );
    expect(gpu_ships_nothing, "gpu-resident ships zero link bytes raw and pushed");
    expect(flops_match, "near-memory FLOPs == off-GPU neighbor slots x dim in every cell");
    expect(
        rows_precision_invariant,
        "dst/neighbor/aggregate row accounting is precision-invariant",
    );
    expect(
        reduction_bitwise,
        "pinned-order reduction is bitwise identical across all modes at each precision",
    );

    // ---- dedup x pushdown composition (duplicated destinations) ----
    let store = build_store(AccessMode::UnifiedAligned, Precision::Fp32, &ranking);
    let sampler = NeighborSampler::new(&graph, &[8], CLASSES);
    let mut rng = Rng::new(0xD0D0);
    let seeds: Vec<u32> = (0..SEEDS_PER_BATCH as u32).map(|i| (i % 9) * 17 % NODES as u32).collect();
    let mb = sampler.sample(&seeds, &mut rng);
    let plan = AggregatePlan::build(&mb).expect("plan");
    let pd_no = store.pushdown_cost(&plan, false).expect("pushdown");
    let pd_de = store.pushdown_cost(&plan, true).expect("pushdown dedup");
    let gplan = GatherPlan::build(&mb.src_nodes);
    let mut x0 = vec![0f32; gplan.requested_rows() * DIM];
    let raw_de = store.gather_planned(&gplan, &mut x0).expect("gather");
    expect(
        pd_de.self_bytes_on_link < pd_no.self_bytes_on_link,
        "dedup shrinks the pushed self stream on duplicated destinations",
    );
    expect(
        pd_de.agg_bytes_on_link == pd_no.agg_bytes_on_link,
        "dedup leaves the aggregate stream untouched",
    );
    expect(
        pd_de.cost.bytes_on_link < raw_de.bytes_on_link,
        "dedup x pushdown still strictly beats the raw deduplicated gather",
    );
}

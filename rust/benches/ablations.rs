//! Ablation benches for the design choices DESIGN.md calls out (beyond the
//! paper's own figures):
//!
//!  A. circular shift on/off across feature widths (generalizes Fig. 7)
//!  B. UVM page size sensitivity (why page migration loses, §3)
//!  C. per-row cudaMemcpy vs batched gather (§2.2's strawman)
//!  D. staging-buffer reuse (allocation churn in the baseline)
//!  E. pipeline queue depth (backpressure window)

mod bench_common;

use bench_common::{expect, scaled};
use ptdirect::config::{AccessMode, SystemProfile};
use ptdirect::coordinator::report::{ms, ratio, Table};
use ptdirect::device::warp::{count_requests, WarpModel};
use ptdirect::featurestore::FeatureStore;
use ptdirect::interconnect::{DmaEngine, PcieLink, UvmSpace};
use ptdirect::pipeline::executor::run_pipeline;
use ptdirect::util::rng::Rng;

fn main() {
    let sys = SystemProfile::system1();
    let mut rng = Rng::new(0xAB1A);

    // ---------------- A: circular shift across widths ----------------
    let mut t = Table::new(
        "Ablation A — circular shift benefit vs feature width",
        &["feat B", "naive reqs", "shifted reqs", "reduction", "amp naive", "amp shifted"],
    );
    let idx: Vec<u32> =
        (0..scaled(16_384, 2_048)).map(|_| rng.gen_range(4_000_000) as u32).collect();
    let mut max_red: f64 = 0.0;
    for feat_bytes in [128u64, 512, 516, 1024, 2052, 4096, 4100, 16384] {
        let f = feat_bytes / 4;
        let naive = count_requests(&idx, f, WarpModel::default(), false);
        let opt = count_requests(&idx, f, WarpModel::default(), true);
        let red = 1.0 - opt.requests as f64 / naive.requests as f64;
        max_red = max_red.max(red);
        t.row(&[
            feat_bytes.to_string(),
            naive.requests.to_string(),
            opt.requests.to_string(),
            format!("{:.1}%", red * 100.0),
            format!("{:.3}", naive.amplification()),
            format!("{:.3}", opt.amplification()),
        ]);
        if feat_bytes % 128 == 0 {
            expect(red.abs() < 1e-9, &format!("{feat_bytes} B aligned: shift is a no-op"));
        }
    }
    t.print();
    expect(max_red > 0.40, "misaligned widths cut ~half the requests");

    // ---------------- B: UVM page size ----------------
    let mut t = Table::new(
        "Ablation B — UVM page-size sensitivity (64K x 1 KiB gather, cold)",
        &["page", "time ms", "amplification", "vs PyD"],
    );
    let idx_small: Vec<u32> =
        (0..scaled(65_536, 8_192)).map(|_| rng.gen_range(4_000_000) as u32).collect();
    let pyd_t = {
        let tr = count_requests(&idx_small, 256, WarpModel::default(), true);
        PcieLink::new(&sys).direct_gather(&tr).time_s
    };
    for page in [4096u64, 16384, 65536, 2 << 20] {
        let mut s = sys.clone();
        s.uvm_page_bytes = page;
        let mut uvm = UvmSpace::new(&s, 0.5);
        let c = uvm.access_rows(&idx_small, 1024);
        t.row(&[
            format!("{} KiB", page >> 10),
            ms(c.time_s),
            format!("{:.1}x", c.bytes_on_link as f64 / c.useful_bytes as f64),
            ratio(c.time_s / pyd_t),
        ]);
        expect(c.time_s > pyd_t, &format!("UVM@{}K slower than PyD zero-copy", page >> 10));
    }
    t.print();

    // ---------------- C: per-row memcpy vs batched gather ----------------
    let dma = DmaEngine::new(&sys);
    let batched = dma.cpu_gather_transfer(32_768, 1024);
    let per_row = dma.per_row_memcpy_transfer(32_768, 1024);
    println!(
        "Ablation C — per-row cudaMemcpy: {} vs batched {} ({}) — the §2.2 strawman\n",
        ms(per_row.time_s),
        ms(batched.time_s),
        ratio(per_row.time_s / batched.time_s)
    );
    expect(per_row.time_s > 10.0 * batched.time_s, "per-row DMA is >10x worse");

    // ---------------- D: staging reuse ----------------
    let store = FeatureStore::build(100_000, 256, 16, AccessMode::CpuGather, &sys, 1).unwrap();
    let gidx: Vec<u32> = (0..8192).map(|_| rng.gen_range(100_000) as u32).collect();
    for _ in 0..10 {
        store.gather(&gidx).unwrap();
    }
    println!(
        "Ablation D — staging pool: {} hits / {} misses over 10 steps\n",
        store_hits(&store),
        store_misses(&store)
    );
    expect(store_hits(&store) >= 9, "staging buffer reused every steady-state step");

    // ---------------- E: queue depth ----------------
    let mut t = Table::new(
        "Ablation E — pipeline queue depth (balanced 1 ms stages, 32 items)",
        &["depth", "wall ms", "overlap", "backpressure ms"],
    );
    for depth in [1usize, 2, 4, 8] {
        let stage = || std::thread::sleep(std::time::Duration::from_millis(1));
        let r = run_pipeline(
            32,
            depth,
            |i| {
                stage();
                Ok(i)
            },
            |b| {
                stage();
                Ok(b)
            },
            |_f| {
                stage();
                Ok(())
            },
        )
        .unwrap();
        let serial = r.stages.sample_s + r.stages.gather_s + r.stages.train_s;
        t.row(&[
            depth.to_string(),
            ms(r.wall_s),
            format!("{:.2}x", serial / r.wall_s),
            ms(r.q1_push_wait_s + r.q2_push_wait_s),
        ]);
    }
    t.print();
}

fn store_hits(s: &FeatureStore) -> u64 {
    s.staging_hits()
}

fn store_misses(s: &FeatureStore) -> u64 {
    s.staging_misses()
}

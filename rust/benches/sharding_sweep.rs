//! Sharding sweep — simulated transfer time vs GPU count and placement
//! policy (DESIGN.md §6; the multi-GPU follow-up arXiv:2103.03330).
//!
//! Acceptance shape for the sharded feature store:
//!
//!  * N = 1 must cost exactly what `Tiered` costs with the same knobs
//!    (the degeneracy contract), and with hot-frac 0 exactly what
//!    `UnifiedAligned` costs;
//!  * transfer time is monotone nonincreasing as N grows 1 -> 8: each GPU
//!    gathers a smaller sub-batch, and peer reads ride NVLink, which is
//!    several times faster than the host PCIe path;
//!  * peer traffic responds to the placement policy: `degree` placement
//!    spreads the globally hottest rows across all shards (high aggregate
//!    hit rate, peer-heavy), while `contig` wastes hot-tier capacity on
//!    cold id ranges (host-heavy) — on an R-MAT graph whose degree
//!    correlates with node id.

mod bench_common;

use bench_common::{expect, replay, scaled, skewed_trace, static_tier_cfg};
use ptdirect::config::{AccessMode, ShardPolicy, SystemProfile};
use ptdirect::coordinator::report::{ms, pct, ratio, Table};
use ptdirect::featurestore::{degree_ranking, FeatureStore, ShardConfig, TierConfig};
use ptdirect::graph::generator::{rmat, RmatParams};
use ptdirect::util::rng::Rng;

const NODES: usize = 20_000;
const EDGES: usize = 200_000;
/// 129 f32 = 516 B rows: misaligned, so cold/peer streams exercise the
/// circular-shift path exactly like `UnifiedAligned` does.
const DIM: usize = 129;
const CLASSES: u32 = 16;
const BATCH_ROWS: usize = 1024;
const SEED: u64 = 42;
const HOT_FRAC: f64 = 0.25;

fn tier_cfg(ranking: Vec<u32>) -> TierConfig {
    static_tier_cfg(HOT_FRAC, ranking)
}

fn sharded_store(num_gpus: usize, policy: ShardPolicy, ranking: Vec<u32>) -> FeatureStore {
    FeatureStore::build_sharded(
        NODES,
        DIM,
        CLASSES,
        &SystemProfile::system1(),
        SEED,
        ShardConfig {
            num_gpus,
            policy,
            tier: tier_cfg(ranking),
            ..ShardConfig::default()
        },
    )
    .expect("sharded store")
}

fn main() {
    let sys = SystemProfile::system1();
    let batches = scaled(64usize, 8);
    let graph = rmat(NODES, EDGES, RmatParams::default(), 0x71E5).expect("graph");
    let mut rng = Rng::new(0x5EE9);
    let trace = skewed_trace(&graph, &mut rng, batches, BATCH_ROWS);
    let ranking = degree_ranking(&graph);

    // Single-GPU references.
    let ua = FeatureStore::build(NODES, DIM, CLASSES, AccessMode::UnifiedAligned, &sys, SEED)
        .expect("unified store");
    let t_ua = replay(&ua, &trace);
    let tiered =
        FeatureStore::build_tiered(NODES, DIM, CLASSES, &sys, SEED, tier_cfg(ranking.clone()))
            .expect("tiered store");
    let t_tiered = replay(&tiered, &trace);

    // ---- GPU-count sweep (hash placement) ----
    let mut t = Table::new(
        &format!(
            "Sharding sweep — {batches} x {BATCH_ROWS}-row degree-skewed gathers, \
             {NODES} x {DIM} f32 table, hot-frac {HOT_FRAC} per shard (System1)"
        ),
        &[
            "N", "policy", "transfer ms", "local %", "peer %", "host %", "imbalance",
            "vs N=1",
        ],
    );
    let mut times = Vec::new();
    for n in [1usize, 2, 4, 8] {
        let store = sharded_store(n, ShardPolicy::Hash, ranking.clone());
        let time = replay(&store, &trace);
        let stats = store.shard_stats().expect("shard stats");
        let totals = stats.totals();
        let rows = totals.rows_served() as f64;
        t.row(&[
            n.to_string(),
            "hash".into(),
            ms(time),
            pct(totals.local_rows as f64 / rows),
            pct(totals.peer_rows as f64 / rows),
            pct(totals.host_rows as f64 / rows),
            ratio(stats.load_imbalance()),
            ratio(time / times.first().copied().unwrap_or(time)),
        ]);
        times.push(time);
    }
    t.print();
    println!(
        "references: UnifiedAligned {} ms, Tiered(hot {HOT_FRAC}) {} ms",
        ms(t_ua),
        ms(t_tiered)
    );

    let rel = |a: f64, b: f64| (a - b).abs() / a.abs().max(1e-12);
    expect(
        rel(times[0], t_tiered) < 1e-12,
        "N=1 reproduces the tiered cost model bit-exactly",
    );
    {
        let cold = FeatureStore::build_sharded(
            NODES,
            DIM,
            CLASSES,
            &sys,
            SEED,
            ShardConfig {
                num_gpus: 1,
                policy: ShardPolicy::Hash,
                tier: TierConfig {
                    hot_frac: 0.0,
                    ..tier_cfg(ranking.clone())
                },
                ..ShardConfig::default()
            },
        )
        .expect("cold sharded store");
        expect(
            rel(replay(&cold, &trace), t_ua) < 1e-12,
            "N=1 at hot-frac 0 reproduces UnifiedAligned exactly",
        );
    }
    let monotone = times.windows(2).all(|w| w[1] <= w[0] + 1e-12);
    expect(monotone, "transfer time monotonically nonincreasing in N (1 -> 8)");
    expect(
        *times.last().unwrap() < times[0],
        "8-way sharding strictly beats a single GPU",
    );

    // ---- placement-policy sweep at N = 4 ----
    let mut pt = Table::new(
        "Placement policies at N = 4 — peer traffic responds to placement",
        &[
            "policy", "transfer ms", "hit rate", "peer rows", "host rows", "imbalance",
        ],
    );
    let mut by_policy = Vec::new();
    for policy in ShardPolicy::all() {
        let store = sharded_store(4, policy, ranking.clone());
        let time = replay(&store, &trace);
        let stats = store.shard_stats().expect("shard stats");
        let totals = stats.totals();
        let rows = totals.rows_served();
        pt.row(&[
            policy.label().into(),
            ms(time),
            pct((totals.local_rows + totals.peer_rows) as f64 / rows as f64),
            totals.peer_rows.to_string(),
            totals.host_rows.to_string(),
            ratio(stats.load_imbalance()),
        ]);
        by_policy.push((policy, totals));
    }
    pt.print();

    let peer = |p: ShardPolicy| {
        by_policy
            .iter()
            .find(|(q, _)| *q == p)
            .map(|(_, t)| t.peer_rows)
            .unwrap_or(0)
    };
    let host = |p: ShardPolicy| {
        by_policy
            .iter()
            .find(|(q, _)| *q == p)
            .map(|(_, t)| t.host_rows)
            .unwrap_or(0)
    };
    expect(
        peer(ShardPolicy::Degree) > peer(ShardPolicy::Contig),
        "degree placement sees more peer traffic than contig (hot rows spread)",
    );
    expect(
        host(ShardPolicy::Contig) > host(ShardPolicy::Degree),
        "contig placement leaks more traffic to the host path than degree",
    );
    expect(
        peer(ShardPolicy::Hash) != peer(ShardPolicy::Contig)
            || host(ShardPolicy::Hash) != host(ShardPolicy::Contig),
        "hash and contig placements produce distinct traffic mixes",
    );
}

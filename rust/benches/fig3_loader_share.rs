//! Paper Fig. 3 — data-loader time share and CPU utilization: CNN vs GNN.
//!
//! The paper's motivation figure: data loading is <1% of CNN training time
//! but 47% (GraphSAGE) / 82% (GAT) of GNN training time, with far higher
//! CPU utilization, because GNN loading gathers scattered rows and builds
//! subgraphs on the CPU.
//!
//! CNN proxy: contiguous batch reads (prefetch pipelines perfectly with the
//! big conv compute).  GNN: the real sampled-gather pipeline on reddit.

mod bench_common;

use bench_common::{bench_steps, expect, scaled};
use ptdirect::config::{AccessMode, RunConfig, SystemProfile};
use ptdirect::coordinator::report::{pct, Table};
use ptdirect::coordinator::Trainer;
use ptdirect::interconnect::DmaEngine;

struct CnnProxy {
    name: &'static str,
    batch_bytes: u64,
    flops_per_batch: f64,
}

/// AlexNet / ResNet-18 on 224x224x3 images, batch 128 (fwd+bwd ~ 3x fwd).
const CNNS: [CnnProxy; 2] = [
    CnnProxy {
        name: "AlexNet",
        batch_bytes: 128 * 224 * 224 * 3 * 4,
        flops_per_batch: 128.0 * 1.4e9 * 3.0,
    },
    CnnProxy {
        name: "ResNet-18",
        batch_bytes: 128 * 224 * 224 * 3 * 4,
        flops_per_batch: 128.0 * 1.8e9 * 3.0,
    },
];

fn main() {
    let sys = SystemProfile::system1();
    let steps = bench_steps(30);
    let mut t = Table::new(
        "Fig. 3 — data loader share + CPU utilization (System1)",
        &["workload", "loader share", "cpu util", "notes"],
    );

    // --- CNNs: contiguous loads overlapped with compute by prefetching ---
    for cnn in CNNS {
        let dma = DmaEngine::new(&sys);
        // image decode/copy is contiguous: full-bandwidth path
        let load_s = dma.dma_time(cnn.batch_bytes) + cnn.batch_bytes as f64 / sys.host_gather_peak;
        let compute_s = cnn.flops_per_batch / (sys.gpu_fp32_flops * 0.35);
        // prefetch hides loading behind compute; only the excess shows up
        let visible_load = (load_s - compute_s).max(0.0) + 0.002 * compute_s;
        let share = visible_load / (visible_load + compute_s);
        let cpu_util = (load_s / compute_s.max(load_s)) * 0.08; // a couple of worker threads
        t.row(&[
            cnn.name.into(),
            pct(share),
            pct(cpu_util),
            "contiguous + prefetch".into(),
        ]);
        expect(share < 0.01, &format!("{} loader share <1%", cnn.name));
    }

    // --- GNNs: the real pipeline on reddit (Py baseline, like Fig. 3) ---
    let mut gnn_shares = Vec::new();
    for arch in ["sage", "gat"] {
        let cfg = RunConfig {
            dataset: "reddit".into(),
            arch: arch.into(),
            mode: AccessMode::CpuGather,
            steps_per_epoch: steps,
            scale: scaled(8, 64),
            feature_budget: 96 << 20,
            skip_train: true,
            seed: 0xF03,
            // Paper-calibrated bands: DGL's loader had no minibatch
            // gather dedup, so pin the legacy duplicated stream.
            dedup: false,
            ..RunConfig::default()
        };
        let mut trainer = Trainer::new(cfg).expect("trainer");
        let r = trainer.run_epoch().expect("epoch");
        let b = &r.breakdown_sim;
        // "data loading" in Fig. 3 = sampling + gather + copy
        let loader = b.sample_s + b.transfer_s;
        let share = loader / b.total_s();
        gnn_shares.push(share);
        t.row(&[
            format!("GraphSAGE/GAT [{arch}] reddit"),
            pct(share),
            pct(r.power.cpu_util),
            "scattered gather + sampling".into(),
        ]);
    }
    t.print();

    println!(
        "GNN loader shares: sage {} gat {} (paper: 47% / 82%)",
        pct(gnn_shares[0]),
        pct(gnn_shares[1])
    );
    // Divergence note (DESIGN.md §7): the paper's DGL GAT example loads
    // *full* neighborhoods (no fan-out sampling), which is why its loader
    // share (82%) exceeds GraphSAGE's; our GAT uses the same sampled
    // fan-outs as SAGE, so its share sits below SAGE's (heavier compute,
    // same bytes).  The figure's core contrast — GNN loading dominates
    // while CNN loading is <1% — reproduces regardless.
    expect(
        (0.40..0.75).contains(&gnn_shares[0]),
        "GraphSAGE loader share ~47-65%",
    );
    expect(
        gnn_shares.iter().all(|&s| s > 0.35),
        "GNN loading dominates vs CNN <1%",
    );
}

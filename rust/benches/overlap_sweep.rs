//! Overlap-engine sweep (DESIGN.md §9): prefetch depth 0 -> 8 across all
//! five storage modes (py, pyd, tiered, sharded, nvme) on the Fig. 8
//! workload.
//!
//! Structural checks (hold at any scale):
//! * depth 0 reproduces the additive serial breakdown bit-exactly,
//! * the overlapped epoch is monotone non-increasing in depth,
//! * it never exceeds the serial sum and never undercuts the busiest
//!   single resource,
//! * depth >= 2 lands strictly below the serial sum for `pyd`
//!   (UnifiedAligned) — sampling hides under the zero-copy transfer.
//!
//! Paper band: with the pipeline enabled, the PyD-over-Py epoch speedup
//! grows past the serial Fig. 8 ratio (the paper's end-to-end ~1.6x claim
//! rides on exactly this overlap); the CPU-centric baseline cannot hide
//! its gather — it fights the sampler for cores — while the GPU-centric
//! modes stream over links the CPU never touches.

mod bench_common;

use bench_common::{bench_steps, expect, scaled};
use ptdirect::config::{AccessMode, RunConfig, ShardPolicy};
use ptdirect::coordinator::report::{critical_path_summary, ms, ratio, Table};
use ptdirect::coordinator::simclock::ResourceKind;
use ptdirect::coordinator::{OverlapReport, Trainer};

const REL_EPS: f64 = 1e-9;

fn mode_cfg(mode: AccessMode, steps: u32) -> RunConfig {
    RunConfig {
        dataset: "product".into(),
        arch: "sage".into(),
        mode,
        steps_per_epoch: steps,
        scale: scaled(256, 2048),
        feature_budget: 96 << 20,
        skip_train: true, // simulated breakdown; e2e runs cover training
        seed: 0xF18,
        // Static placement: identical gather traffic at every depth, so
        // the per-depth comparisons are bit-reproducible.
        tier_promote: false,
        num_gpus: if mode == AccessMode::Sharded { 4 } else { 1 },
        shard_policy: ShardPolicy::Degree,
        host_frac: 0.5,
        // The paper's ~1.6x pipelined-speedup band predates the gather
        // dedup; pin the legacy stream so the depth-sweep comparisons
        // stay calibrated (dedup_sweep covers the dedup-on story).
        dedup: false,
        ..RunConfig::default()
    }
}

/// Sweep one mode over depths 0..=8; returns the per-depth overlap
/// reports (index == depth).
fn sweep(mode: AccessMode, steps: u32) -> Vec<OverlapReport> {
    let mut trainer = Trainer::new(mode_cfg(mode, steps)).expect("trainer");
    let label = mode.label();
    let mut t = Table::new(
        &format!("overlap sweep — {label} (product, System1, {steps} steps)"),
        &["depth", "serial ms", "overlapped ms", "speedup", "bound by"],
    );
    let mut reports = Vec::new();
    for depth in 0..=8u32 {
        trainer.cfg.prefetch_depth = depth;
        let r = trainer.run_epoch().expect("epoch");
        let o = r.overlap;
        if depth == 0 {
            expect(
                o.overlapped_s == r.breakdown_sim.total_s(),
                &format!("{label}: depth 0 bit-exact with the serial breakdown"),
            );
        }
        t.row(&[
            depth.to_string(),
            ms(o.serial_s),
            ms(o.overlapped_s),
            ratio(o.speedup()),
            o.bound_by.label().into(),
        ]);
        reports.push(o);
    }
    t.print();
    println!("  depth 8 critical path: {}", critical_path_summary(&reports[8]));

    // Structural bounds across the sweep.
    let mut monotone = true;
    let mut bounded = true;
    for pair in reports.windows(2) {
        monotone &= pair[1].overlapped_s <= pair[0].overlapped_s * (1.0 + REL_EPS);
    }
    for o in &reports {
        bounded &= o.overlapped_s <= o.serial_s * (1.0 + REL_EPS);
        for kind in ResourceKind::all() {
            // The sampler is multi-lane; its busy time bounds the epoch
            // only after dividing by the lane count (1 in this config).
            let lanes = if kind == ResourceKind::Sampler {
                trainer.cfg.sampler_workers.max(1) as f64
            } else {
                1.0
            };
            bounded &= o.overlapped_s >= o.busy.get(kind) / lanes - REL_EPS * o.serial_s;
        }
    }
    expect(monotone, &format!("{label}: overlapped time monotone in depth"));
    expect(
        bounded,
        &format!("{label}: overlapped in [max resource busy, serial sum]"),
    );
    reports
}

fn main() {
    let steps = bench_steps(30);
    let modes = [
        AccessMode::CpuGather,
        AccessMode::UnifiedAligned,
        AccessMode::Tiered,
        AccessMode::Sharded,
        AccessMode::Nvme,
    ];
    let mut by_mode = Vec::new();
    for mode in modes {
        by_mode.push((mode, sweep(mode, steps)));
    }

    // --- the acceptance contract: pyd overlaps strictly at depth >= 2 ---
    let pyd = &by_mode[1].1;
    expect(
        pyd[2].overlapped_s < pyd[2].serial_s,
        "pyd: depth 2 strictly below the serial sum",
    );

    // --- paper band: PyD over Py, serial vs pipelined (Fig. 8 + §5.3) ---
    let py = &by_mode[0].1;
    let serial_speedup = py[0].serial_s / pyd[0].serial_s;
    let piped_speedup = py[4].overlapped_s / pyd[4].overlapped_s;
    println!(
        "PyD over Py: serial {} -> pipelined (depth 4) {} (paper: serial \
         1.01x-1.45x, ~1.6x end-to-end once the copy hides under compute)",
        ratio(serial_speedup),
        ratio(piped_speedup),
    );
    expect(
        piped_speedup >= serial_speedup * 0.95,
        "pipelining does not erode the PyD advantage",
    );
    expect(
        (1.0..3.0).contains(&piped_speedup),
        "pipelined PyD-over-Py speedup within the paper band",
    );
}

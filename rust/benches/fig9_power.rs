//! Paper Fig. 9 — whole-system power, Py vs PyD, GraphSAGE + GAT across the
//! Table-4 datasets (System1; meter-level affine model, idle ≈ 105 W).
//!
//! Paper band: PyD saves 12.4%–17.5% of system power during training.

mod bench_common;

use bench_common::{bench_steps, expect, scaled};
use ptdirect::config::{AccessMode, RunConfig};
use ptdirect::coordinator::report::{pct, Table};
use ptdirect::coordinator::Trainer;
use ptdirect::graph::datasets::DATASETS;

fn main() {
    let steps = bench_steps(30);
    let mut savings = Vec::new();

    for arch in ["sage", "gat"] {
        let mut t = Table::new(
            &format!("Fig. 9 — {arch} system power (System1, idle 105 W)"),
            &["dataset", "Py W", "PyD W", "saving", "Py cpu util", "PyD cpu util"],
        );
        for d in DATASETS {
            if arch == "gat" && d.abbv == "sk" {
                continue;
            }
            let base = RunConfig {
                dataset: d.abbv.into(),
                arch: arch.into(),
                steps_per_epoch: steps,
                scale: scaled(256, 2048),
                feature_budget: 96 << 20,
                skip_train: true,
                seed: 0xF19,
                // Paper-calibrated bands: the Fig. 9 testbed had no
                // minibatch gather dedup (see fig8_epoch_breakdown).
                dedup: false,
                ..RunConfig::default()
            };
            let mut reports = Vec::new();
            for mode in [AccessMode::CpuGather, AccessMode::UnifiedAligned] {
                let mut trainer =
                    Trainer::new(RunConfig { mode, ..base.clone() }).expect("trainer");
                reports.push(trainer.run_epoch().expect("epoch"));
            }
            let (py, pyd) = (&reports[0], &reports[1]);
            let saving = 1.0 - pyd.power.watts / py.power.watts;
            savings.push(saving);
            t.row(&[
                d.abbv.into(),
                format!("{:.0}", py.power.watts),
                format!("{:.0}", pyd.power.watts),
                pct(saving),
                pct(py.power.cpu_util),
                pct(pyd.power.cpu_util),
            ]);
        }
        t.print();
    }

    let avg = savings.iter().sum::<f64>() / savings.len() as f64;
    let (min_s, max_s) = (
        savings.iter().cloned().fold(f64::MAX, f64::min),
        savings.iter().cloned().fold(0.0, f64::max),
    );
    println!("power saving {:.1}%..{:.1}% avg {:.1}% (paper 12.4%..17.5%)",
        min_s * 100.0, max_s * 100.0, avg * 100.0);
    expect(min_s > 0.05, "PyD always saves power");
    expect((0.08..0.25).contains(&avg), "avg power saving in/near paper band");
}

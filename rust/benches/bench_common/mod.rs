//! Shared helpers for the paper-figure benches (criterion is not vendored;
//! each bench is a `harness = false` binary that measures, checks the
//! paper-shape assertions, and prints a table).

#![allow(dead_code)]

use ptdirect::featurestore::{FeatureStore, TierConfig};
use ptdirect::graph::Csr;
use ptdirect::util::rng::Rng;
use ptdirect::util::stats::Summary;
use ptdirect::util::timer::Timer;

/// Repeat a closure and collect wall-clock stats (for measured-here parts).
pub fn measure<F: FnMut()>(warmup: u32, iters: u32, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let t = Timer::start();
        f();
        s.add(t.elapsed_s());
    }
    s
}

/// Whether the bench was invoked with `--quick` (the CI smoke
/// configuration: tiny scale, full code path, seconds not minutes).
/// Exact-shape checks (endpoint bit-exactness, monotonicity) hold at any
/// scale; paper-band checks may print CHECK lines at smoke scale, which
/// the smoke step ignores — it only gates on the bench running to
/// completion.
pub fn quick() -> bool {
    std::env::args().any(|a| a == "--quick")
}

/// Pick the full-scale or `--quick` value for a bench-size knob.
pub fn scaled<T>(full: T, quick_val: T) -> T {
    if quick() {
        quick_val
    } else {
        full
    }
}

/// Bench-scale knob: PTDIRECT_BENCH_STEPS (default given per bench;
/// `--quick` caps it at 3 for the CI smoke run).
pub fn bench_steps(default: u32) -> u32 {
    let steps = std::env::var("PTDIRECT_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default);
    if quick() {
        steps.min(3)
    } else {
        steps
    }
}

/// Degree-proportional access trace shared by the tier/shard/storage
/// sweeps: pick a uniform random *edge* and take its source, so a node's
/// draw probability is its out-degree share — the frequency profile
/// neighbor-sampled training induces, and a power-law under R-MAT.
pub fn skewed_trace(
    graph: &Csr,
    rng: &mut Rng,
    batches: usize,
    batch_rows: usize,
) -> Vec<Vec<u32>> {
    let mut edge_src = vec![0u32; graph.num_edges()];
    for v in 0..graph.num_nodes() as u32 {
        let lo = graph.indptr[v as usize] as usize;
        let hi = graph.indptr[v as usize + 1] as usize;
        for s in &mut edge_src[lo..hi] {
            *s = v;
        }
    }
    (0..batches)
        .map(|_| {
            (0..batch_rows)
                .map(|_| edge_src[rng.gen_range_usize(edge_src.len())])
                .collect()
        })
        .collect()
}

/// Replay a gather trace against a store; returns total simulated
/// transfer seconds.  Shared by the tier/shard/storage sweeps so their
/// cross-bench degeneracy comparisons price traces identically.
pub fn replay(store: &FeatureStore, trace: &[Vec<u32>]) -> f64 {
    let mut total = 0.0;
    for batch in trace {
        let (_, cost) = store.gather(batch).expect("gather");
        total += cost.time_s;
    }
    total
}

/// Static (promotion-off) tier configuration shared by the sweep benches:
/// deterministic placement, so comparisons across stores and benches stay
/// bit-reproducible.
pub fn static_tier_cfg(hot_frac: f64, ranking: Vec<u32>) -> TierConfig {
    TierConfig {
        hot_frac,
        reserve_bytes: 0,
        promote: false,
        ranking: Some(ranking),
        ..TierConfig::default()
    }
}

/// Soft assertion: print PASS/CHECK lines instead of panicking so a bench
/// always produces its full table; failures are grep-able.
pub fn expect(cond: bool, what: &str) {
    if cond {
        println!("PASS  {what}");
    } else {
        println!("CHECK {what}  <-- outside paper band");
    }
}

//! Shared helpers for the paper-figure benches (criterion is not vendored;
//! each bench is a `harness = false` binary that measures, checks the
//! paper-shape assertions, and prints a table).

#![allow(dead_code)]

use ptdirect::util::stats::Summary;
use ptdirect::util::timer::Timer;

/// Repeat a closure and collect wall-clock stats (for measured-here parts).
pub fn measure<F: FnMut()>(warmup: u32, iters: u32, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let t = Timer::start();
        f();
        s.add(t.elapsed_s());
    }
    s
}

/// Bench-scale knob: PTDIRECT_BENCH_STEPS (default given per bench).
pub fn bench_steps(default: u32) -> u32 {
    std::env::var("PTDIRECT_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// Soft assertion: print PASS/CHECK lines instead of panicking so a bench
/// always produces its full table; failures are grep-able.
pub fn expect(cond: bool, what: &str) {
    if cond {
        println!("PASS  {what}");
    } else {
        println!("CHECK {what}  <-- outside paper band");
    }
}

//! Throughput sweep — wall-clock planned-gather throughput across the
//! `--precision` x `--sampler-workers` grid (DESIGN.md §13).
//!
//! Every other bench in this suite reports *simulated* seconds; this one
//! measures the real thing: elapsed wall-clock of the measured host-side
//! gather + scatter copies (`FeatureStore::gather_planned`) as worker
//! threads and storage precision vary.  The structural invariants ride
//! along:
//!
//!  * gathered bytes are bitwise invariant in the worker count (the
//!    knob buys wall-clock only, at every precision);
//!  * the fp32 column reproduces the plain (unquantized) builder's
//!    gather bit-exactly — the pinned degeneracy anchor;
//!  * simulated link bytes strictly shrink fp32 -> fp16 -> int8, and
//!    are identical across worker counts within a precision.
//!
//! Emits `BENCH_throughput.json`.  Structural fields are derived purely
//! from simulated quantities and are byte-identical across runs; the
//! wall-clock measurements live on their own lines under keys prefixed
//! `wall_`, which the CI determinism gate strips (`grep -v '"wall_'`)
//! before digesting.

mod bench_common;

use bench_common::{expect, measure, scaled};
use ptdirect::config::{AccessMode, Precision, SystemProfile};
use ptdirect::coordinator::report::Table;
use ptdirect::featurestore::FeatureStore;
use ptdirect::sampler::GatherPlan;
use ptdirect::util::rng::Rng;

/// Misaligned 516 B fp32 rows (129 floats), the suite's standard
/// cacheline-unfriendly shape: 129 elements span 5/3/2 cachelines at
/// fp32/fp16/int8, so every precision step narrows the request stream.
const DIM: usize = 129;
const CLASSES: u32 = 16;
const SEED: u64 = 42;

const WORKERS: [usize; 4] = [1, 2, 4, 8];

/// Minimal JSON string escape (labels here are plain ASCII).
fn json_str(s: &str) -> String {
    format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\""))
}

fn main() {
    let rows: usize = scaled(40_000, 4_000);
    let batches: usize = scaled(24, 4);
    let batch_rows: usize = scaled(4_096, 512);
    let iters: u32 = scaled(5, 2);

    // Duplicated skewed id stream -> one plan per batch (the trainer's
    // dedup path, where the scatter copy actually runs).
    let mut rng = Rng::new(0x7B06);
    let plans: Vec<GatherPlan> = (0..batches)
        .map(|_| {
            let idx: Vec<u32> = (0..batch_rows)
                .map(|_| (rng.gen_range(rows as u64 / 2) + rng.gen_range(rows as u64 / 2)) as u32)
                .collect();
            GatherPlan::build(&idx)
        })
        .collect();
    let out_len: usize = plans.iter().map(|p| p.requested_rows()).max().unwrap() * DIM;
    let sys = SystemProfile::system1();

    // Degeneracy anchor: the plain builder's gather, workers = 1, fp32.
    let plain = FeatureStore::build(rows, DIM, CLASSES, AccessMode::UnifiedAligned, &sys, SEED)
        .expect("plain store");
    let mut anchor = vec![0f32; out_len];
    let mut anchor_out: Vec<Vec<f32>> = Vec::new();
    for p in &plans {
        anchor[..p.requested_rows() * DIM].fill(0.0);
        plain
            .gather_planned(p, &mut anchor[..p.requested_rows() * DIM])
            .expect("anchor gather");
        anchor_out.push(anchor[..p.requested_rows() * DIM].to_vec());
    }

    let mut t = Table::new(
        &format!(
            "Throughput sweep — {batches} x {batch_rows}-row planned gathers, \
             {rows} x {DIM} table (wall-clock, System1 pricing)"
        ),
        &["precision", "workers", "link MB", "requests", "rows/s", "ms/epoch"],
    );
    let mut json_rows = Vec::new();
    let mut bitwise_invariant = true;
    let mut cost_invariant = true;
    let mut fp32_anchor_holds = true;
    let mut link_bytes_by_precision = Vec::new();

    for precision in Precision::all() {
        let mut reference: Option<(Vec<Vec<f32>>, u64, u64)> = None;
        for &workers in &WORKERS {
            let mut store = FeatureStore::build_quantized(
                rows,
                DIM,
                CLASSES,
                AccessMode::UnifiedAligned,
                &sys,
                SEED,
                precision,
                None,
                None,
                None,
            )
            .expect("quantized store");
            store.set_gather_workers(workers);

            // One checked pass for values + simulated cost...
            let mut out = vec![0f32; out_len];
            let mut gathered: Vec<Vec<f32>> = Vec::new();
            let (mut bytes_on_link, mut requests, mut total_rows) = (0u64, 0u64, 0u64);
            for p in &plans {
                let dst = &mut out[..p.requested_rows() * DIM];
                dst.fill(0.0);
                let cost = store.gather_planned(p, dst).expect("gather");
                bytes_on_link += cost.bytes_on_link;
                requests += cost.requests;
                total_rows += p.requested_rows() as u64;
                gathered.push(dst.to_vec());
            }
            match &reference {
                None => {
                    if precision == Precision::Fp32 {
                        fp32_anchor_holds &= gathered == anchor_out;
                    }
                    reference = Some((gathered, bytes_on_link, requests));
                }
                Some((ref_out, ref_bytes, ref_reqs)) => {
                    bitwise_invariant &= &gathered == ref_out;
                    cost_invariant &= bytes_on_link == *ref_bytes && requests == *ref_reqs;
                }
            }

            // ...then the timed passes (wall-clock only; values already
            // pinned above).
            let wall = measure(1, iters, || {
                for p in &plans {
                    store
                        .gather_planned(p, &mut out[..p.requested_rows() * DIM])
                        .expect("gather");
                }
            });
            let epoch_s = wall.median().max(1e-12);
            let rows_per_s = total_rows as f64 / epoch_s;

            t.row(&[
                precision.label().into(),
                workers.to_string(),
                format!("{:.2}", bytes_on_link as f64 / 1e6),
                requests.to_string(),
                format!("{rows_per_s:.3e}"),
                format!("{:.2}", epoch_s * 1e3),
            ]);
            json_rows.push(format!(
                "    {{\"precision\": {}, \"workers\": {}, \"row_bytes\": {}, \
                 \"bytes_on_link\": {}, \"requests\": {}, \"rows\": {},\n     \
                 \"wall_epoch_ms_p50\": {:.4}, \"wall_rows_per_s\": {:.1}}}",
                json_str(precision.label()),
                workers,
                precision.row_bytes(DIM),
                bytes_on_link,
                requests,
                total_rows,
                epoch_s * 1e3,
                rows_per_s,
            ));
        }
        let (_, bytes, _) = reference.expect("at least one worker count ran");
        link_bytes_by_precision.push(bytes);
    }
    t.print();

    let json = format!(
        "{{\n  \"bench\": \"throughput_sweep\", \"rows\": {rows}, \"dim\": {DIM}, \
         \"batches\": {batches}, \"batch_rows\": {batch_rows},\n  \"cells\": [\n{}\n  ]\n}}\n",
        json_rows.join(",\n")
    );
    std::fs::write("BENCH_throughput.json", &json).expect("write BENCH_throughput.json");
    println!("wrote BENCH_throughput.json ({} cells)", json_rows.len());

    // ---- structural checks ----
    expect(
        fp32_anchor_holds,
        "fp32 planned gather reproduces the unquantized builder bit-exactly",
    );
    expect(
        bitwise_invariant,
        "gathered bytes bitwise invariant in worker count at every precision",
    );
    expect(
        cost_invariant,
        "simulated link bytes/requests invariant in worker count at every precision",
    );
    expect(
        link_bytes_by_precision.windows(2).all(|w| w[0] > w[1])
            && *link_bytes_by_precision.last().unwrap() > 0,
        "link bytes strictly shrink fp32 -> fp16 -> int8",
    );
}

//! Paper Fig. 6 — irregular host-access microbenchmark.
//!
//! Grid: N ∈ {8K, 32K, 128K, 256K} features × S ∈ {256 B, 1 KiB, 4 KiB,
//! 16 KiB} per feature, on the three Table-5 systems, comparing the
//! CPU-centric baseline (Py), PyTorch-Direct zero-copy (PyD) and the ideal
//! pure-payload transfer.
//!
//! Paper bands: Py 1.85–2.82x slower than ideal on System1, 3.31–5.01x on
//! System2; PyD 1.03–1.20x everywhere except the tiny (8K, 256 B) corner;
//! PyD beats Py by ~2.39x on average.

mod bench_common;

use bench_common::{expect, quick};
use ptdirect::config::SystemProfile;
use ptdirect::coordinator::microbench::{fig6_grid, run_cell};
use ptdirect::coordinator::report::{ms, ratio, Table};
use ptdirect::util::bytes::human_bytes;
use ptdirect::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(0xF16);
    let (mut ns, mut sizes) = fig6_grid();
    if quick() {
        // CI smoke: a 2x2 corner of the grid (keeps non-tiny cells so the
        // band stats stay defined; paper bands may print CHECK at this
        // scale, which the smoke step ignores).
        ns.truncate(2);
        sizes.truncate(2);
    }
    let mut all_speedups = Vec::new();

    for sys in SystemProfile::all() {
        let mut t = Table::new(
            &format!("Fig. 6 — {} ({} + {})", sys.name, sys.cpu_name, sys.gpu_name),
            &["N", "feat", "ideal ms", "Py ms", "PyD ms", "Py/ideal", "PyD/ideal", "PyD vs Py"],
        );
        let mut py_slow = Vec::new();
        let mut pyd_slow = Vec::new();
        for &n in &ns {
            for &s in &sizes {
                let c = run_cell(&sys, n, s, &mut rng);
                t.row(&[
                    format!("{}K", n >> 10),
                    human_bytes(s),
                    ms(c.ideal_s),
                    ms(c.py_s),
                    ms(c.pyd_s),
                    ratio(c.py_slowdown()),
                    ratio(c.pyd_slowdown()),
                    ratio(c.pyd_speedup_over_py()),
                ]);
                let tiny_corner = n == 8 << 10 && s == 256;
                if !tiny_corner {
                    py_slow.push(c.py_slowdown());
                    pyd_slow.push(c.pyd_slowdown());
                    all_speedups.push(c.pyd_speedup_over_py());
                }
            }
        }
        t.print();
        let (py_min, py_max) = (
            py_slow.iter().cloned().fold(f64::MAX, f64::min),
            py_slow.iter().cloned().fold(0.0, f64::max),
        );
        let pyd_max = pyd_slow.iter().cloned().fold(0.0, f64::max);
        println!(
            "{}: Py slowdown {:.2}x..{:.2}x, PyD max slowdown {:.2}x\n",
            sys.name, py_min, py_max, pyd_max
        );
        match sys.name {
            "System1" => {
                expect((1.6..2.3).contains(&py_min), "System1 Py min slowdown ~1.85x");
                expect((2.3..3.3).contains(&py_max), "System1 Py max slowdown ~2.82x");
            }
            "System2" => {
                expect((2.8..3.8).contains(&py_min), "System2 Py min slowdown ~3.31x");
                expect((4.3..5.6).contains(&py_max), "System2 Py max slowdown ~5.01x");
            }
            _ => {}
        }
        expect(pyd_max < 1.25, &format!("{} PyD within 1.03-1.20x of ideal", sys.name));
    }

    let avg = all_speedups.iter().sum::<f64>() / all_speedups.len() as f64;
    println!("average PyD speedup over Py: {avg:.2}x (paper: ~2.39x)");
    expect((1.9..2.9).contains(&avg), "average PyD speedup ~2.39x");
}

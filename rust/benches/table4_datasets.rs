//! Paper Table 4 — dataset inventory, full-scale stats vs the generated
//! scaled graphs (degree preservation check).

mod bench_common;

use bench_common::{expect, scaled};
use ptdirect::coordinator::report::Table;
use ptdirect::graph::datasets::DATASETS;
use ptdirect::util::bytes::human_bytes;

fn main() {
    let scale = scaled(1024u32, 8192);
    let mut t = Table::new(
        &format!("Table 4 — datasets (full scale | generated at 1/{scale})"),
        &["abbv", "#feat", "size", "#node", "#edge", "gen nodes", "gen edges", "deg err"],
    );
    for d in DATASETS {
        let g = d.build_graph(scale, 0x7AB1E4).expect("generator");
        g.validate().expect("csr invariants");
        let want_deg = d.edges as f64 / d.nodes as f64;
        let got_deg = g.avg_degree();
        let deg_err = (got_deg - want_deg).abs() / want_deg;
        t.row(&[
            d.abbv.into(),
            d.feat_dim.to_string(),
            human_bytes(d.feature_bytes()),
            format!("{:.1}M", d.nodes as f64 / 1e6),
            format!("{:.1}M", d.edges as f64 / 1e6),
            g.num_nodes().to_string(),
            g.num_edges().to_string(),
            format!("{:.2}%", deg_err * 100.0),
        ]);
        expect(deg_err < 0.05, &format!("{}: avg degree preserved at scale", d.abbv));
    }
    t.print();

    // Paper Table 4 magnitude checks ("Size" column).
    let gb = |abbv: &str| {
        DATASETS
            .iter()
            .find(|d| d.abbv == abbv)
            .unwrap()
            .feature_bytes() as f64
            / 1e9
    };
    expect((gb("twit") - 57.0).abs() < 3.0, "twitter7 feature table ~57 GB");
    expect((gb("sk") - 59.0).abs() < 3.0, "sk-2005 ~59 GB");
    expect((gb("paper") - 57.0).abs() < 3.0, "ogbn-papers100M ~57 GB");
    expect((gb("wiki") - 44.0).abs() < 3.0, "wikipedia_link_en ~44 GB");
    expect((gb("product") - 0.96).abs() < 0.1, "ogbn-products ~960 MB");
}

//! Dedup sweep — transfer reduction from minibatch gather deduplication
//! (DESIGN.md §10; arXiv:2103.03330, GIDS arXiv:2306.16384).
//!
//! Acceptance shape (EXPERIMENTS.md documents the expected curves):
//!
//!  * on a degree-skewed trace, the planned (deduplicated) gather moves
//!    strictly fewer link bytes than the naive duplicated gather in every
//!    transfer-paying mode, and never costs more simulated time;
//!  * gathered values are bitwise identical either way (scatter ∘
//!    gather-unique is the identity on row values);
//!  * the dedup ratio of real neighbor-sampled minibatches grows with
//!    fanout — deeper/wider sampling overlaps more, so the traffic the
//!    compaction removes grows with exactly the configurations that hurt
//!    the naive path most.

mod bench_common;

use bench_common::{expect, scaled, skewed_trace, static_tier_cfg};
use ptdirect::config::{AccessMode, ShardPolicy, SystemProfile};
use ptdirect::coordinator::report::{ms, ratio, Table};
use ptdirect::featurestore::{
    degree_ranking, FeatureStore, NvmeStoreConfig, ShardConfig,
};
use ptdirect::graph::generator::{rmat, RmatParams};
use ptdirect::sampler::{GatherPlan, NeighborSampler};
use ptdirect::util::bytes::human_bytes;
use ptdirect::util::rng::Rng;

const NODES: usize = 20_000;
const EDGES: usize = 200_000;
/// 129 f32 = 516 B rows: misaligned, so the circular-shift path runs.
const DIM: usize = 129;
const CLASSES: u32 = 16;
const BATCH_ROWS: usize = 1024;
const SEED: u64 = 42;
const HOT_FRAC: f64 = 0.1;

/// Build one store per compared mode with shared placement knobs.
fn build_store(mode: AccessMode, ranking: &[u32]) -> FeatureStore {
    let sys = SystemProfile::system1();
    match mode {
        AccessMode::Tiered => FeatureStore::build_tiered(
            NODES,
            DIM,
            CLASSES,
            &sys,
            SEED,
            static_tier_cfg(HOT_FRAC, ranking.to_vec()),
        ),
        AccessMode::Sharded => FeatureStore::build_sharded(
            NODES,
            DIM,
            CLASSES,
            &sys,
            SEED,
            ShardConfig {
                num_gpus: 4,
                policy: ShardPolicy::Degree,
                tier: static_tier_cfg(HOT_FRAC, ranking.to_vec()),
                ..ShardConfig::default()
            },
        ),
        AccessMode::Nvme => FeatureStore::build_nvme(
            NODES,
            DIM,
            CLASSES,
            &sys,
            SEED,
            NvmeStoreConfig {
                host_frac: 0.5,
                tier: static_tier_cfg(HOT_FRAC, ranking.to_vec()),
            },
        ),
        _ => FeatureStore::build(NODES, DIM, CLASSES, mode, &sys, SEED),
    }
    .expect("store")
}

/// Replay a trace naively (duplicated stream); returns (seconds, bytes).
fn replay_naive(store: &FeatureStore, trace: &[Vec<u32>]) -> (f64, u64) {
    let (mut time, mut bytes) = (0.0, 0u64);
    for batch in trace {
        let (_, cost) = store.gather(batch).expect("gather");
        time += cost.time_s;
        bytes += cost.bytes_on_link;
    }
    (time, bytes)
}

/// Replay a trace through per-batch [`GatherPlan`]s; returns
/// (seconds, bytes, requested rows, unique rows).
fn replay_planned(store: &FeatureStore, trace: &[Vec<u32>]) -> (f64, u64, u64, u64) {
    let (mut time, mut bytes) = (0.0, 0u64);
    let (mut requested, mut unique) = (0u64, 0u64);
    let mut out = Vec::new();
    for batch in trace {
        let plan = GatherPlan::build(batch);
        out.resize(plan.requested_rows() * DIM, 0.0f32);
        let cost = store.gather_planned(&plan, &mut out).expect("planned gather");
        time += cost.time_s;
        bytes += cost.bytes_on_link;
        requested += plan.requested_rows() as u64;
        unique += plan.unique_rows() as u64;
    }
    (time, bytes, requested, unique)
}

fn main() {
    let batches = scaled(64usize, 8);
    let graph = rmat(NODES, EDGES, RmatParams::default(), 0x71E5).expect("graph");
    let mut rng = Rng::new(0x5EEA);
    let trace = skewed_trace(&graph, &mut rng, batches, BATCH_ROWS);
    let ranking = degree_ranking(&graph);

    // ---- per-mode on/off comparison ----
    let modes = [
        AccessMode::CpuGather,
        AccessMode::UnifiedNaive,
        AccessMode::UnifiedAligned,
        AccessMode::Tiered,
        AccessMode::Sharded,
        AccessMode::Nvme,
    ];
    let mut t = Table::new(
        &format!(
            "Dedup sweep — {batches} x {BATCH_ROWS}-row degree-skewed gathers, \
             {NODES} x {DIM} f32 table (System1)"
        ),
        &[
            "mode", "requested", "unique", "ratio", "naive B", "dedup B", "B saved",
            "naive ms", "dedup ms", "speedup",
        ],
    );
    let mut all_bytes_strict = true;
    let mut all_time_sane = true;
    for &mode in &modes {
        let (naive_s, naive_b) = replay_naive(&build_store(mode, &ranking), &trace);
        let (dedup_s, dedup_b, req, uniq) =
            replay_planned(&build_store(mode, &ranking), &trace);
        all_bytes_strict &= dedup_b < naive_b;
        all_time_sane &= dedup_s <= naive_s + 1e-15;
        t.row(&[
            mode.label().into(),
            req.to_string(),
            uniq.to_string(),
            ratio(req as f64 / uniq.max(1) as f64),
            human_bytes(naive_b),
            human_bytes(dedup_b),
            human_bytes(naive_b.saturating_sub(dedup_b)),
            ms(naive_s),
            ms(dedup_s),
            ratio(naive_s / dedup_s.max(1e-12)),
        ]);
    }
    t.print();
    expect(
        all_bytes_strict,
        "dedup strictly reduces link bytes in every transfer-paying mode",
    );
    expect(all_time_sane, "dedup never increases simulated transfer time");

    // ---- numerics: scatter ∘ gather-unique == naive gather ----
    let probe = &trace[0];
    let st = build_store(AccessMode::UnifiedAligned, &ranking);
    let (naive_vals, _) = st.gather(probe).expect("gather");
    let plan = GatherPlan::build(probe);
    let mut planned_vals = vec![0.0f32; plan.requested_rows() * DIM];
    build_store(AccessMode::UnifiedAligned, &ranking)
        .gather_planned(&plan, &mut planned_vals)
        .expect("planned gather");
    expect(
        planned_vals == naive_vals,
        "planned gather bitwise identical to the naive gather",
    );

    // ---- dedup ratio vs fanout on real neighbor-sampled batches ----
    let mut t = Table::new(
        "Dedup ratio vs fanout — 512-root minibatches on the R-MAT graph",
        &["fanouts", "requested/batch", "unique/batch", "ratio"],
    );
    let n_batches = scaled(8usize, 2);
    let mut ratios = Vec::new();
    for fanout in [3usize, 5, 10, 15] {
        let sampler = NeighborSampler::new(&graph, &[fanout, fanout], CLASSES);
        let mut srng = Rng::new(0xFA0);
        let (mut req, mut uniq) = (0u64, 0u64);
        for b in 0..n_batches {
            let seeds: Vec<u32> =
                (0..512u32).map(|k| (b as u32 * 512 + k * 7) % NODES as u32).collect();
            let mb = sampler.sample(&seeds, &mut srng);
            let plan = mb.compact();
            req += plan.requested_rows() as u64;
            uniq += plan.unique_rows() as u64;
        }
        let r = req as f64 / uniq.max(1) as f64;
        t.row(&[
            format!("[{fanout}, {fanout}]"),
            (req / n_batches as u64).to_string(),
            (uniq / n_batches as u64).to_string(),
            ratio(r),
        ]);
        ratios.push(r);
    }
    t.print();
    expect(
        ratios.iter().all(|&r| r >= 1.0),
        "dedup ratio >= 1 at every fanout",
    );
    expect(
        ratios.windows(2).all(|w| w[1] >= w[0] - 1e-9),
        "dedup ratio grows with fanout (overlap compounds)",
    );
    expect(
        *ratios.last().unwrap() > 1.5,
        "wide fanouts produce substantial duplication on a skewed graph",
    );
}

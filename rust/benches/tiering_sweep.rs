//! Tiering sweep — simulated transfer time vs hot-set fraction.
//!
//! The acceptance shape for the tiered feature store (see README "Tiered
//! access mode" and the Data Tiering paper, arXiv:2111.05894):
//!
//!  * hot-frac 0 must cost exactly what `UnifiedAligned` costs (the cold
//!    tier *is* that path);
//!  * hot-frac 1 must cost what `GpuResident` costs (kernel launch only);
//!  * in between, transfer time interpolates monotonically downward;
//!  * on a degree-skewed (power-law) trace, a 25% hot set already beats
//!    `UnifiedAligned` — frequency follows degree, so the top-degree
//!    prefix absorbs most of the traffic.
//!
//! A second table replays the same epoch against a *cold* cache with LFU
//! promotion enabled: the hit rate climbs epoch over epoch (cache warming).

mod bench_common;

use bench_common::{expect, replay as replay_time, scaled, skewed_trace};
use ptdirect::config::{AccessMode, SystemProfile};
use ptdirect::coordinator::report::{ms, pct, ratio, Table};
use ptdirect::featurestore::{degree_ranking, FeatureStore, TierConfig};
use ptdirect::graph::generator::{rmat, RmatParams};
use ptdirect::util::rng::Rng;

const NODES: usize = 20_000;
const EDGES: usize = 200_000;
/// 129 f32 = 516 B rows: misaligned, so the cold tier exercises the
/// circular-shift path exactly like `UnifiedAligned` does.
const DIM: usize = 129;
const CLASSES: u32 = 16;
const BATCH_ROWS: usize = 1024;
const SEED: u64 = 42;

/// Replay the trace (the shared `bench_common::replay` pricing); returns
/// (total simulated transfer seconds, this replay's hit rate).
fn replay(store: &FeatureStore, trace: &[Vec<u32>]) -> (f64, f64) {
    let before = store.tier_stats();
    let total = replay_time(store, trace);
    let hit_rate = match (store.tier_stats(), before) {
        (Some(now), Some(b)) => now.since(&b).hit_rate(),
        (Some(now), None) => now.hit_rate(),
        _ => 0.0,
    };
    (total, hit_rate)
}

fn tiered_store(hot_frac: f64, promote: bool, ranking: Option<Vec<u32>>) -> FeatureStore {
    FeatureStore::build_tiered(
        NODES,
        DIM,
        CLASSES,
        &SystemProfile::system1(),
        SEED,
        TierConfig {
            hot_frac,
            reserve_bytes: 0,
            promote,
            ranking,
            ..TierConfig::default()
        },
    )
    .expect("tiered store")
}

fn main() {
    let sys = SystemProfile::system1();
    let batches = scaled(64usize, 8);
    let graph = rmat(NODES, EDGES, RmatParams::default(), 0x71E5).expect("graph");
    let mut rng = Rng::new(0x5EE9);
    let trace = skewed_trace(&graph, &mut rng, batches, BATCH_ROWS);
    let ranking = degree_ranking(&graph);

    let ua = FeatureStore::build(NODES, DIM, CLASSES, AccessMode::UnifiedAligned, &sys, SEED)
        .expect("unified store");
    let (t_ua, _) = replay(&ua, &trace);
    let gpu = FeatureStore::build(NODES, DIM, CLASSES, AccessMode::GpuResident, &sys, SEED)
        .expect("gpu store");
    let (t_gpu, _) = replay(&gpu, &trace);

    // ---- static degree-ranked sweep ----
    let mut t = Table::new(
        &format!(
            "Tiering sweep — {batches} x {BATCH_ROWS}-row degree-skewed gathers, \
             {NODES} x {DIM} f32 table (System1)"
        ),
        &["hot frac", "hot rows", "hit rate", "transfer ms", "vs PyD", "vs GPU-res"],
    );
    let mut times = Vec::new();
    let mut t_quarter = f64::NAN;
    for i in 0..=10 {
        let frac = i as f64 / 10.0;
        let store = tiered_store(frac, false, Some(ranking.clone()));
        let (time, hit_rate) = replay(&store, &trace);
        let stats = store.tier_stats().expect("tier stats");
        t.row(&[
            format!("{frac:.1}"),
            stats.hot_rows.to_string(),
            pct(hit_rate),
            ms(time),
            ratio(time / t_ua),
            ratio(time / t_gpu),
        ]);
        times.push(time);
    }
    {
        let store = tiered_store(0.25, false, Some(ranking.clone()));
        let (time, hit_rate) = replay(&store, &trace);
        t.row(&[
            "0.25".into(),
            store.tier_stats().unwrap().hot_rows.to_string(),
            pct(hit_rate),
            ms(time),
            ratio(time / t_ua),
            ratio(time / t_gpu),
        ]);
        t_quarter = time;
    }
    t.print();
    println!("endpoints: PyD {} ms, GPU-resident {} ms", ms(t_ua), ms(t_gpu));

    let rel = |a: f64, b: f64| (a - b).abs() / a.abs().max(1e-12);
    expect(
        rel(times[0], t_ua) < 1e-9,
        "hot-frac 0 matches UnifiedAligned exactly",
    );
    expect(
        rel(times[10], t_gpu) < 1e-9,
        "hot-frac 1 matches GpuResident (kernel-launch epsilon)",
    );
    let monotone = times.windows(2).all(|w| w[1] <= w[0] + 1e-12);
    expect(monotone, "transfer time monotonically nonincreasing in hot-frac");
    expect(
        times[10] < times[0],
        "fully-hot tier strictly beats fully-cold",
    );
    expect(
        t_quarter < t_ua,
        "25% hot set beats UnifiedAligned on the skewed trace",
    );

    // ---- LFU warming: cold start, promotion on ----
    let mut warm = Table::new(
        "LFU warming — hot-frac 0.25, cold start, same epoch replayed",
        &["epoch", "hit rate", "transfer ms", "promotions", "hot rows"],
    );
    let store = tiered_store(0.25, true, None);
    let mut rates = Vec::new();
    for epoch in 0..3 {
        let snap = store.tier_stats().unwrap();
        let (time, hit_rate) = replay(&store, &trace);
        let delta = store.tier_stats().unwrap().since(&snap);
        warm.row(&[
            epoch.to_string(),
            pct(hit_rate),
            ms(time),
            delta.promotions.to_string(),
            delta.hot_rows.to_string(),
        ]);
        rates.push(hit_rate);
    }
    warm.print();
    expect(rates[0] < rates[2], "promotion warms the cache epoch over epoch");
    expect(
        store.tier_stats().unwrap().hot_bytes <= store.tier_stats().unwrap().capacity_bytes,
        "hot bytes never exceed the configured budget",
    );
}

//! Serving sweep — tail latency, goodput, and coalescing payoff of the
//! online inference engine (`--serve`, DESIGN.md §11).
//!
//! Acceptance shape (EXPERIMENTS.md documents the expected curves):
//!
//!  * mean latency is monotone non-decreasing in the open-loop arrival
//!    rate (coalescing off: fixed service order, compressed arrivals);
//!  * at a loaded arrival rate, coalescing fetches strictly fewer unique
//!    rows than the uncoalesced run requests, and merges batches;
//!  * a single closed-loop client reproduces the batch inference runner's
//!    simulated breakdown bit-exactly (the degeneracy anchor);
//!  * a burst over a shallow admission queue sheds load:
//!    `admitted + rejected == offered` with `rejected > 0`.
//!
//! Emits `BENCH_serving.json` (p50/p95/p99 + goodput per access mode at
//! the loaded rate) for the CI smoke loop and trend tracking.

mod bench_common;

use bench_common::{expect, scaled};
use ptdirect::config::{AccessMode, Backend, RunConfig, ShardPolicy};
use ptdirect::coordinator::report::{latency_line, ms, ratio, Table};
use ptdirect::coordinator::{InferenceRunner, ServingEngine, ServingReport};

const SEED: u64 = 42;

/// Hermetic serving config: native backend, no artifacts, small graph.
fn cfg(mode: AccessMode, requests: u64, rps: f64) -> RunConfig {
    RunConfig {
        dataset: "product".into(),
        arch: "sage".into(),
        mode,
        scale: 2048,
        feature_budget: 8 << 20,
        seed: SEED,
        backend: Backend::Native,
        artifacts_dir: "this-directory-does-not-exist".into(),
        num_gpus: if mode == AccessMode::Sharded { 4 } else { 1 },
        shard_policy: ShardPolicy::Degree,
        serve_requests: requests,
        arrival_rps: rps,
        admit_depth: 4096, // no shedding in the sweeps; shedding is probed separately
        ..RunConfig::default()
    }
}

fn serve(c: RunConfig) -> ServingReport {
    ServingEngine::new(c).expect("engine").run().expect("serve")
}

/// Minimal JSON string escape (keys/labels here are plain ASCII).
fn json_str(s: &str) -> String {
    format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\""))
}

fn main() {
    let requests = scaled(96u64, 24);

    // ---- rps sweep x coalescing (pyd mode) ----
    let rates = [500.0, 5_000.0, 50_000.0, 500_000.0];
    let mut t = Table::new(
        &format!(
            "Serving sweep — {requests} requests, open-loop Poisson arrivals, \
             pyd mode (System1)"
        ),
        &[
            "rps", "coalesce", "batches", "req/batch", "dedup", "p50 ms", "p99 ms",
            "goodput rps",
        ],
    );
    let mut means = Vec::new();
    for &rps in &rates {
        for coalesce in [true, false] {
            let mut c = cfg(AccessMode::UnifiedAligned, requests, rps);
            c.coalesce = coalesce;
            let r = serve(c);
            if !coalesce {
                means.push(r.latency.mean());
            }
            t.row(&[
                format!("{rps:.0}"),
                if coalesce { "on" } else { "off" }.into(),
                r.batches.to_string(),
                format!("{:.2}", r.coalesce_factor()),
                ratio(r.dedup_ratio()),
                ms(r.latency.percentile(0.50)),
                ms(r.latency.percentile(0.99)),
                format!("{:.0}", r.goodput_rps()),
            ]);
        }
    }
    t.print();
    expect(
        means.windows(2).all(|w| w[1] >= w[0] - 1e-12),
        "mean latency monotone non-decreasing in arrival rate (coalesce off)",
    );

    // ---- per-mode table at the loaded rate (+ JSON emission) ----
    let loaded = 50_000.0;
    let mut t = Table::new(
        &format!("Serving per mode — {requests} requests at {loaded:.0} rps offered"),
        &["mode", "p50 ms", "p95 ms", "p99 ms", "goodput rps", "req/batch", "bound by"],
    );
    let mut json_rows = Vec::new();
    let mut coalesce_saves_rows = true;
    for mode in AccessMode::all() {
        let r = serve(cfg(mode, requests, loaded));
        let mut un = cfg(mode, requests, loaded);
        un.coalesce = false;
        let r_un = serve(un);
        coalesce_saves_rows &= r.unique_rows < r_un.requested_rows;
        t.row(&[
            mode.label().into(),
            ms(r.latency.percentile(0.50)),
            ms(r.latency.percentile(0.95)),
            ms(r.latency.percentile(0.99)),
            format!("{:.0}", r.goodput_rps()),
            format!("{:.2}", r.coalesce_factor()),
            r.bound_by.label().into(),
        ]);
        json_rows.push(format!(
            "    {{\"mode\": {}, \"p50_ms\": {:.6}, \"p95_ms\": {:.6}, \
             \"p99_ms\": {:.6}, \"goodput_rps\": {:.3}, \"coalesce_factor\": {:.4}, \
             \"rejection_rate\": {:.4}}}",
            json_str(mode.label()),
            r.latency.percentile(0.50) * 1e3,
            r.latency.percentile(0.95) * 1e3,
            r.latency.percentile(0.99) * 1e3,
            r.goodput_rps(),
            r.coalesce_factor(),
            r.rejection_rate(),
        ));
        println!("{}: {}", mode.label(), latency_line(&r.latency));
    }
    t.print();
    expect(
        coalesce_saves_rows,
        "coalesced gather fetches fewer unique rows than the uncoalesced run requests",
    );
    let json = format!(
        "{{\n  \"bench\": \"serving_sweep\", \"requests\": {requests}, \
         \"arrival_rps\": {loaded:.1},\n  \"modes\": [\n{}\n  ]\n}}\n",
        json_rows.join(",\n")
    );
    std::fs::write("BENCH_serving.json", &json).expect("write BENCH_serving.json");
    println!("wrote BENCH_serving.json ({} modes)", AccessMode::all().len());

    // ---- single-client closed-loop degeneracy vs the batch runner ----
    let mut c = cfg(AccessMode::UnifiedAligned, requests, 0.0);
    c.clients = 1;
    let r = serve(c.clone());
    let infer = InferenceRunner::new(c)
        .expect("runner")
        .run(requests)
        .expect("infer");
    let (a, b) = (&r.breakdown_sim, &infer.breakdown_sim);
    expect(
        a.sample_s == b.sample_s && a.transfer_s == b.transfer_s && a.train_s == b.train_s,
        "single closed-loop client bitwise reproduces the batch inference breakdown",
    );
    expect(r.batches == requests, "one client never coalesces");

    // ---- admission shedding under a hard burst ----
    let mut c = cfg(AccessMode::CpuGather, requests, 1_000_000.0);
    c.admit_depth = 2;
    let r = serve(c);
    expect(
        r.admitted + r.rejected == r.offered,
        "admission books balance (admitted + rejected == offered)",
    );
    expect(
        r.rejected > 0 && r.completed == r.admitted,
        "a burst over a depth-2 queue sheds load and serves the rest",
    );
}

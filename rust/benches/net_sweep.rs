//! Net sweep — multi-host scaling over the network tier (DESIGN.md §15).
//!
//! Replays the shared degree-skewed trace against sharded stores spanning
//! hosts 1 -> 8 × placement policy × fetch strategy:
//!
//!  * the `--num-hosts 1` cell must reproduce the plain single-host
//!    sharded replay bit-exactly under every policy and strategy (the
//!    degeneracy anchor of the topology refactor);
//!  * `partition-local` never pays the network at any host count — its
//!    cost is bitwise the single-host cost, and only the halo counter
//!    records the replicated rows a real deployment would store;
//!  * `remote-fetch` remote bytes grow monotonically with the host count
//!    under every policy (host 0's shard only shrinks as hosts double),
//!    and the network is priced exactly when remote rows exist;
//!  * rows served is conserved across every cell (homing rows remotely
//!    reclassifies traffic, it never invents or drops rows);
//!  * widening the network link monotonically shrinks the time spent on
//!    it (the NetLink bandwidth/latency price responds to the knobs).
//!
//! Emits `BENCH_net.json` — one record per grid cell, derived purely from
//! simulated quantities, so back-to-back runs are byte-identical (the CI
//! smoke loop diffs two digests).

mod bench_common;

use bench_common::{expect, replay, scaled, skewed_trace, static_tier_cfg};
use ptdirect::config::{FetchStrategy, ShardPolicy, SystemProfile};
use ptdirect::coordinator::report::{ms, ratio, Table};
use ptdirect::featurestore::{degree_ranking, FeatureStore, GpuShardStats, ShardConfig};
use ptdirect::graph::generator::{rmat, RmatParams};
use ptdirect::util::bytes::human_bytes;
use ptdirect::util::rng::Rng;

const NODES: usize = 20_000;
const EDGES: usize = 200_000;
/// Misaligned 516 B rows so every path prices like `UnifiedAligned`.
const DIM: usize = 129;
const CLASSES: u32 = 16;
const BATCH_ROWS: usize = 1024;
const SEED: u64 = 42;
const HOT_FRAC: f64 = 0.25;
const NUM_GPUS: usize = 2;

const HOSTS: [u32; 4] = [1, 2, 4, 8];

/// Minimal JSON string escape (labels here are plain ASCII).
fn json_str(s: &str) -> String {
    format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\""))
}

fn store(
    sys: &SystemProfile,
    num_hosts: u32,
    policy: ShardPolicy,
    strategy: FetchStrategy,
    ranking: Vec<u32>,
) -> FeatureStore {
    FeatureStore::build_sharded(
        NODES,
        DIM,
        CLASSES,
        sys,
        SEED,
        ShardConfig {
            num_gpus: NUM_GPUS,
            num_hosts,
            policy,
            fetch_strategy: strategy,
            tier: static_tier_cfg(HOT_FRAC, ranking),
            ..ShardConfig::default()
        },
    )
    .expect("sharded store")
}

fn main() {
    let sys = SystemProfile::system1();
    let batches = scaled(64usize, 8);
    let graph = rmat(NODES, EDGES, RmatParams::default(), 0x71E5).expect("graph");
    let mut rng = Rng::new(0x5EE9);
    let trace = skewed_trace(&graph, &mut rng, batches, BATCH_ROWS);
    let ranking = degree_ranking(&graph);

    // Single-host reference per policy: a plain ShardConfig (no host
    // knobs at all) — the anchor every hosts=1 cell must reproduce.
    let anchor: Vec<f64> = ShardPolicy::all()
        .iter()
        .map(|&policy| {
            let st = FeatureStore::build_sharded(
                NODES,
                DIM,
                CLASSES,
                &sys,
                SEED,
                ShardConfig {
                    num_gpus: NUM_GPUS,
                    policy,
                    tier: static_tier_cfg(HOT_FRAC, ranking.clone()),
                    ..ShardConfig::default()
                },
            )
            .expect("anchor store");
            replay(&st, &trace)
        })
        .collect();

    let mut t = Table::new(
        &format!(
            "Net sweep — {batches} x {BATCH_ROWS}-row degree-skewed gathers, \
             {NODES} x {DIM} f32 table, {NUM_GPUS} GPUs/host, hot-frac {HOT_FRAC} (System1)"
        ),
        &[
            "hosts", "policy", "strategy", "transfer ms", "remote rows", "halo rows",
            "remote B", "net ms", "vs 1 host",
        ],
    );
    let mut json_rows = Vec::new();
    let mut anchored = true;
    let mut local_degenerate = true;
    let mut remote_monotone = true;
    let mut net_priced_iff_remote = true;
    let mut rows_conserved = true;
    let mut remote_at_8 = true;

    for (pi, &policy) in ShardPolicy::all().iter().enumerate() {
        for strategy in FetchStrategy::all() {
            let mut base_time = f64::NAN;
            let mut base_rows = 0u64;
            let mut prev_remote = 0u64;
            for &hosts in &HOSTS {
                let st = store(&sys, hosts, policy, strategy, ranking.clone());
                let time = replay(&st, &trace);
                let stats = st.shard_stats().expect("shard stats");
                let totals: GpuShardStats = stats.totals();

                if hosts == 1 {
                    base_time = time;
                    base_rows = totals.rows_served();
                    anchored &= time == anchor[pi];
                }
                match strategy {
                    // Replication is cost-degenerate at every host count.
                    FetchStrategy::PartitionLocal => {
                        local_degenerate &= time == base_time
                            && totals.remote_rows == 0
                            && totals.remote_bytes == 0
                            && totals.net_time_s == 0.0;
                    }
                    // Host 0's shard only shrinks as hosts double.
                    FetchStrategy::RemoteFetch => {
                        remote_monotone &= totals.remote_bytes >= prev_remote;
                        prev_remote = totals.remote_bytes;
                        if hosts == 8 {
                            remote_at_8 &= totals.remote_bytes > 0;
                        }
                    }
                }
                net_priced_iff_remote &=
                    (totals.net_time_s > 0.0) == (totals.remote_bytes > 0);
                // Halo rows are double-listed (their normal class plus the
                // halo counter), so rows_served alone is the conserved sum.
                rows_conserved &= totals.rows_served() == base_rows;

                t.row(&[
                    hosts.to_string(),
                    policy.label().into(),
                    strategy.label().into(),
                    ms(time),
                    totals.remote_rows.to_string(),
                    totals.halo_rows.to_string(),
                    human_bytes(totals.remote_bytes),
                    ms(totals.net_time_s),
                    ratio(time / base_time),
                ]);
                json_rows.push(format!(
                    "    {{\"hosts\": {}, \"policy\": {}, \"strategy\": {}, \
                     \"transfer_ms\": {:.6}, \"remote_rows\": {}, \"halo_rows\": {}, \
                     \"remote_bytes\": {}, \"net_ms\": {:.6}, \"imbalance\": {:.6}}}",
                    hosts,
                    json_str(policy.label()),
                    json_str(strategy.label()),
                    time * 1e3,
                    totals.remote_rows,
                    totals.halo_rows,
                    totals.remote_bytes,
                    totals.net_time_s * 1e3,
                    stats.load_imbalance(),
                ));
            }
        }
    }
    t.print();

    expect(
        anchored,
        "hosts=1 reproduces the plain sharded replay bit-exactly under every policy/strategy",
    );
    expect(
        local_degenerate,
        "partition-local costs bitwise the single-host epoch at every host count",
    );
    expect(
        remote_monotone,
        "remote-fetch bytes monotone non-decreasing as hosts grow 1 -> 8, every policy",
    );
    expect(remote_at_8, "an 8-host split homes rows remotely under every policy");
    expect(
        net_priced_iff_remote,
        "the network lane is priced exactly when remote rows exist",
    );
    expect(rows_conserved, "rows served conserved across every cell of the grid");

    // ---- network-link sensitivity at 4 hosts, hash, remote-fetch ----
    // `--net-gb-per-s`/`--net-latency-us` reach the NetLink price: a
    // link that is strictly wider and lower-latency can only shrink the
    // time spent on it.
    let mut nt = Table::new(
        "Net-link sensitivity — 4 hosts, hash placement, remote-fetch",
        &["net GB/s", "latency us", "net ms", "transfer ms"],
    );
    let mut net_times = Vec::new();
    for (bw_gb, lat_us) in [(3.125, 15.0), (12.5, 10.0), (25.0, 2.0), (100.0, 1.0)] {
        let mut s = SystemProfile::system1();
        s.net.peak_bw = bw_gb * 1e9;
        s.net.latency_s = lat_us * 1e-6;
        let st = store(&s, 4, ShardPolicy::Hash, FetchStrategy::RemoteFetch, ranking.clone());
        let time = replay(&st, &trace);
        let totals = st.shard_stats().expect("shard stats").totals();
        nt.row(&[
            format!("{bw_gb}"),
            format!("{lat_us}"),
            ms(totals.net_time_s),
            ms(time),
        ]);
        net_times.push(totals.net_time_s);
    }
    nt.print();
    expect(
        net_times.windows(2).all(|w| w[1] <= w[0] + 1e-15),
        "net time monotone non-increasing as the link widens and latency drops",
    );
    expect(
        net_times[0] > *net_times.last().unwrap(),
        "a 32x wider link strictly beats the slow-Ethernet price",
    );

    let json = format!(
        "{{\n  \"bench\": \"net_sweep\", \"nodes\": {NODES}, \"dim\": {DIM}, \
         \"batches\": {batches}, \"batch_rows\": {BATCH_ROWS}, \"num_gpus\": {NUM_GPUS},\n  \
         \"cells\": [\n{}\n  ]\n}}\n",
        json_rows.join(",\n")
    );
    std::fs::write("BENCH_net.json", &json).expect("write BENCH_net.json");
    println!("wrote BENCH_net.json ({} cells)", json_rows.len());
}

//! Paper Fig. 8 — single-epoch execution-time breakdown, Py vs PyD, for
//! GraphSAGE and GAT across the six Table-4 datasets (System1 testbed).
//!
//! Paper bands: feature-copy time drops ~47.1% on average; end-to-end
//! speedup 1.01x–1.45x; the non-copy components stay almost identical;
//! small-feature datasets (paper) benefit least; GAT benefits less than
//! GraphSAGE (compute-heavier).
//!
//! The breakdown here is the *simulated-testbed* estimate (DESIGN.md §5)
//! over really-sampled batches and really-counted gather traffic; set
//! PTDIRECT_BENCH_STEPS to change the per-config step count (default 30).

mod bench_common;

use bench_common::{bench_steps, expect, scaled};
use ptdirect::config::{AccessMode, RunConfig};
use ptdirect::coordinator::report::{ms, pct, ratio, Table};
use ptdirect::coordinator::Trainer;
use ptdirect::graph::datasets::DATASETS;

fn main() {
    let steps = bench_steps(30);
    let mut copy_reductions = Vec::new();
    let mut speedups = Vec::new();

    for arch in ["sage", "gat"] {
        let mut t = Table::new(
            &format!("Fig. 8 — {arch} epoch breakdown (System1, {steps} steps/config)"),
            &[
                "dataset", "mode", "sample ms", "copy ms", "train ms", "other ms", "epoch ms",
                "copy cut", "speedup",
            ],
        );
        for d in DATASETS {
            // Paper skips GAT on sk (DGL out-of-host-memory); mirror that.
            if arch == "gat" && d.abbv == "sk" {
                continue;
            }
            let base = RunConfig {
                dataset: d.abbv.into(),
                arch: arch.into(),
                steps_per_epoch: steps,
                scale: scaled(256, 2048),
                feature_budget: 96 << 20,
                skip_train: true, // simulated breakdown; e2e runs cover PJRT
                seed: 0xF18,
                // The paper's testbed had no minibatch gather dedup; pin
                // the legacy duplicated stream so the Fig. 8 bands stay
                // calibrated (dedup_sweep covers the dedup-on story).
                dedup: false,
                ..RunConfig::default()
            };
            let mut epochs = Vec::new();
            for mode in [AccessMode::CpuGather, AccessMode::UnifiedAligned] {
                let mut trainer =
                    Trainer::new(RunConfig { mode, ..base.clone() }).expect("trainer");
                epochs.push(trainer.run_epoch().expect("epoch"));
            }
            let (py, pyd) = (&epochs[0], &epochs[1]);
            let copy_cut = 1.0 - pyd.breakdown_sim.transfer_s / py.breakdown_sim.transfer_s;
            let speedup = py.breakdown_sim.total_s() / pyd.breakdown_sim.total_s();
            copy_reductions.push(copy_cut);
            speedups.push(speedup);
            for (r, mode) in [(py, "Py"), (pyd, "PyD")] {
                let b = &r.breakdown_sim;
                t.row(&[
                    d.abbv.into(),
                    mode.into(),
                    ms(b.sample_s),
                    ms(b.transfer_s),
                    ms(b.train_s),
                    ms(b.other_s),
                    ms(b.total_s()),
                    if mode == "PyD" { pct(copy_cut) } else { "-".into() },
                    if mode == "PyD" { ratio(speedup) } else { "-".into() },
                ]);
            }
        }
        t.print();
    }

    let avg_cut = copy_reductions.iter().sum::<f64>() / copy_reductions.len() as f64;
    let (min_sp, max_sp) = (
        speedups.iter().cloned().fold(f64::MAX, f64::min),
        speedups.iter().cloned().fold(0.0, f64::max),
    );
    println!("feature-copy reduction avg {} (paper ~47.1%)", pct(avg_cut));
    println!("end-to-end speedup {:.2}x..{:.2}x (paper 1.01x..1.45x)", min_sp, max_sp);
    expect((0.35..0.60).contains(&avg_cut), "avg feature-copy reduction ~47.1%");
    expect(min_sp >= 1.0, "PyD never slower end-to-end");
    expect((1.2..1.7).contains(&max_sp), "max end-to-end speedup ~1.45x");
}

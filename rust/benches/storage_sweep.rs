//! Storage sweep — simulated transfer time vs the host-resident fraction
//! of the feature table (DESIGN.md §8; GIDS, arXiv:2306.16384).
//!
//! Acceptance shape for the NVMe three-tier store (EXPERIMENTS.md
//! documents the expected curve):
//!
//!  * host-frac 1 must cost exactly what `Tiered` costs with the same
//!    knobs — and therefore exactly what `Sharded` N=1 costs (the
//!    degeneracy chain extends one tier down);
//!  * transfer time grows monotonically as host-frac drops from 1.0 to
//!    0.1: every row that spills trades a cacheline-granular PCIe
//!    zero-copy read for a block-granular NVMe read that is slower per
//!    byte *and* per command;
//!  * block-read I/O amplification is >= 1 whenever storage is touched,
//!    and adjacent-row traces coalesce into fewer IOs than scattered
//!    ones (the read-coalescing model).

mod bench_common;

use bench_common::{expect, replay, scaled, skewed_trace, static_tier_cfg};
use ptdirect::config::{ShardPolicy, SystemProfile};
use ptdirect::coordinator::report::{ms, pct, ratio, Table};
use ptdirect::featurestore::{
    degree_ranking, FeatureStore, NvmeStoreConfig, ShardConfig, TierConfig,
};
use ptdirect::graph::generator::{rmat, RmatParams};
use ptdirect::interconnect::count_block_ios;
use ptdirect::util::rng::Rng;

const NODES: usize = 20_000;
const EDGES: usize = 200_000;
/// 129 f32 = 516 B rows: misaligned for the host zero-copy path (the
/// circular-shift model applies) and sub-block for the storage path
/// (4 KiB blocks hold ~7.9 rows, so spill-layout adjacency matters).
const DIM: usize = 129;
const CLASSES: u32 = 16;
const BATCH_ROWS: usize = 1024;
const SEED: u64 = 42;
const HOT_FRAC: f64 = 0.1;

fn tier_cfg(ranking: Vec<u32>) -> TierConfig {
    static_tier_cfg(HOT_FRAC, ranking)
}

fn nvme_store(host_frac: f64, ranking: Vec<u32>) -> FeatureStore {
    FeatureStore::build_nvme(
        NODES,
        DIM,
        CLASSES,
        &SystemProfile::system1(),
        SEED,
        NvmeStoreConfig {
            host_frac,
            tier: tier_cfg(ranking),
        },
    )
    .expect("nvme store")
}

fn main() {
    let sys = SystemProfile::system1();
    let batches = scaled(64usize, 8);
    let graph = rmat(NODES, EDGES, RmatParams::default(), 0x71E5).expect("graph");
    let mut rng = Rng::new(0x5EEA);
    let trace = skewed_trace(&graph, &mut rng, batches, BATCH_ROWS);
    let ranking = degree_ranking(&graph);

    // Single-tier references with the same hot-tier knobs.
    let tiered =
        FeatureStore::build_tiered(NODES, DIM, CLASSES, &sys, SEED, tier_cfg(ranking.clone()))
            .expect("tiered store");
    let t_tiered = replay(&tiered, &trace);
    let sharded = FeatureStore::build_sharded(
        NODES,
        DIM,
        CLASSES,
        &sys,
        SEED,
        ShardConfig {
            num_gpus: 1,
            policy: ShardPolicy::Hash,
            tier: tier_cfg(ranking.clone()),
            ..ShardConfig::default()
        },
    )
    .expect("sharded store");
    let t_sharded = replay(&sharded, &trace);

    // ---- host-frac sweep, 1.0 -> 0.1 ----
    let mut t = Table::new(
        &format!(
            "Storage sweep — {batches} x {BATCH_ROWS}-row degree-skewed gathers, \
             {NODES} x {DIM} f32 table, hot-frac {HOT_FRAC} (System1)"
        ),
        &[
            "host frac", "spilled", "gpu %", "host %", "storage %", "IOs", "amp",
            "transfer ms", "vs frac 1",
        ],
    );
    let fracs = [1.0f64, 0.9, 0.75, 0.5, 0.25, 0.1];
    let mut times = Vec::new();
    let mut amps = Vec::new();
    for &frac in &fracs {
        let store = nvme_store(frac, ranking.clone());
        let time = replay(&store, &trace);
        let stats = store.nvme_stats().expect("nvme stats");
        let rows = stats.rows_served() as f64;
        t.row(&[
            format!("{frac:.2}"),
            stats.spilled_rows.to_string(),
            pct(stats.tier.hits as f64 / rows),
            pct(stats.host_rows as f64 / rows),
            pct(stats.storage_rows as f64 / rows),
            stats.ios.to_string(),
            format!("{:.2}x", stats.amplification()),
            ms(time),
            ratio(time / times.first().copied().unwrap_or(time)),
        ]);
        times.push(time);
        amps.push((frac, stats.storage_rows, stats.amplification()));
    }
    t.print();
    println!(
        "references: Tiered(hot {HOT_FRAC}) {} ms, Sharded N=1 {} ms",
        ms(t_tiered),
        ms(t_sharded)
    );

    let rel = |a: f64, b: f64| (a - b).abs() / a.abs().max(1e-12);
    expect(
        rel(times[0], t_tiered) < 1e-12,
        "host-frac 1 reproduces the tiered cost model bit-exactly",
    );
    expect(
        rel(times[0], t_sharded) < 1e-12,
        "host-frac 1 reproduces the sharded N=1 cost model bit-exactly",
    );
    let monotone = times.windows(2).all(|w| w[1] >= w[0] - 1e-12);
    expect(
        monotone,
        "transfer time monotonically nondecreasing as host-frac drops 1.0 -> 0.1",
    );
    expect(
        *times.last().unwrap() > times[0],
        "a 10% host tier strictly costs more than fully host-resident",
    );
    expect(
        amps.iter()
            .filter(|&&(_, rows, _)| rows > 0)
            .all(|&(_, _, a)| a >= 1.0 - 1e-12),
        "block-read I/O amplification >= 1 whenever storage is touched",
    );
    expect(
        amps.iter().all(|&(frac, rows, _)| frac < 1.0 || rows == 0),
        "host-frac 1 never reads storage",
    );
    expect(
        // The coldest spilled ranks can be degree-0 nodes a
        // degree-proportional trace never draws, so near-1 fractions may
        // legitimately stay storage-quiet; by half-spilled the trace must
        // be hitting storage.
        amps.iter().any(|&(frac, rows, _)| frac <= 0.5 && rows > 0),
        "a half-spilled table sees storage traffic",
    );

    // ---- read coalescing: adjacent vs scattered spilled rows ----
    // The spilled cold store packs rows in id order, so an id-adjacent
    // request set shares 4 KiB blocks while an id-strided one cannot.
    let row_bytes = DIM as u64 * 4;
    let n = 512u32;
    let adjacent: Vec<u32> = (0..n).collect();
    let scattered: Vec<u32> = (0..n).map(|i| i * 64).collect();
    let t_adj = count_block_ios(&adjacent, row_bytes, sys.nvme.block_bytes);
    let t_sca = count_block_ios(&scattered, row_bytes, sys.nvme.block_bytes);
    println!(
        "coalescing: {} adjacent rows -> {} IOs (amp {:.2}x); scattered -> {} IOs (amp {:.2}x)",
        n,
        t_adj.ios,
        t_adj.amplification(),
        t_sca.ios,
        t_sca.amplification()
    );
    expect(
        t_adj.ios < t_sca.ios,
        "adjacent spilled rows coalesce into fewer block reads than scattered",
    );
    expect(
        t_adj.amplification() < t_sca.amplification(),
        "coalescing shrinks I/O amplification",
    );
}
